//! Collectives over [`channel`](super::channel): ring all-reduce and
//! broadcast. These carry real tensor data between TP workers — the SPMD
//! "distributed operations" of the paper's distributed runtime (§4.1.1).
//!
//! The ring all-reduce is the textbook 2(n-1)-step algorithm: n-1
//! reduce-scatter steps followed by n-1 all-gather steps over equal chunks,
//! which is also the cost model `topology::allreduce_time` assumes.
//!
//! # §Perf: the zero-copy wire
//!
//! Chunk payloads are recyclable [`ArenaBuf`]s: the sender checks a chunk
//! buffer out of its thread-local arena shelf, the receiver reduces from it
//! and drops it, which returns it to the *receiver's* shelf. Because every
//! ring step sends and receives exactly one chunk, each endpoint's shelf
//! stays balanced and steady-state calls perform **zero heap allocations**
//! (asserted in `tests/zero_copy.rs`). Empty chunks (`len < n`) are not
//! sent at all — both sides compute identical chunk bounds and skip the
//! matching send/recv. Broadcast ships one `Arc`-shared buffer to every
//! receiver: no per-receiver clone, and receivers get a zero-copy shared
//! tensor.
//!
//! The pre-arena allocating implementations are kept in [`reference`] for
//! differential tests and the before/after hot-path bench.

use super::channel::Endpoint;
use crate::memory::arena::{ArenaBuf, ArenaPool};
use crate::tensor::{Storage, Tensor};
use std::sync::Arc;

/// A chunk payload on the wire.
pub enum WireBuf {
    /// Exclusively-owned chunk — usually arena-checked-out; dropping it on
    /// the receive side shelves the buffer in the receiver's arena.
    Excl(ArenaBuf),
    /// One buffer shared by every receiver (broadcast): cloning the message
    /// clones an `Arc`, never the data.
    Shared(Arc<ArenaBuf>),
}

impl WireBuf {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            WireBuf::Excl(b) => b.as_slice(),
            WireBuf::Shared(a) => a.as_slice(),
        }
    }
}

/// Message payload for collectives: chunk index + recyclable buffer.
pub struct ChunkMsg {
    pub idx: usize,
    pub buf: WireBuf,
}

/// Start of chunk `i` when `len` splits into `n` near-equal pieces.
#[inline]
fn chunk_start(len: usize, n: usize, i: usize) -> usize {
    let (base, rem) = (len / n, len % n);
    i * base + i.min(rem)
}

/// Bounds [a, b) of chunk `i`.
#[inline]
fn chunk_bound(len: usize, n: usize, i: usize) -> (usize, usize) {
    let (base, rem) = (len / n, len % n);
    let a = chunk_start(len, n, i);
    (a, a + base + usize::from(i < rem))
}

/// Ring all-reduce (sum) across `group` (world ranks, including our own).
/// Every member calls this with its local partial; all return the sum.
///
/// `ep` is this worker's endpoint; `group` must list ranks in the same
/// order on every participant. Allocation-free at steady state: chunk
/// buffers cycle between the participants' arena shelves.
pub fn ring_allreduce(ep: &Endpoint<ChunkMsg>, group: &[usize], mut t: Tensor) -> Tensor {
    let n = group.len();
    if n <= 1 {
        return t;
    }
    // (§Perf note: a whole-tensor exchange fast path for n=2 was tried and
    // measured ~35% SLOWER than the ring on this testbed — the ring's two
    // half-size messages pipeline better with the single-core scheduler —
    // so the generic ring is kept for all group sizes. See EXPERIMENTS.md.)
    let me = group.iter().position(|&r| r == ep.rank).expect("rank not in group");
    let next = group[(me + 1) % n];
    let prev = group[(me + n - 1) % n];
    let len = t.len();
    let data: &mut [f32] = &mut t.data;

    // Phase 1: reduce-scatter. After step s, rank me owns the full sum of
    // chunk (me + 1) mod n ... converging so chunk (me+1)%n is complete.
    for s in 0..n - 1 {
        let send_idx = (me + n - s) % n;
        let (a, b) = chunk_bound(len, n, send_idx);
        if b > a {
            let mut buf = ArenaPool::checkout(b - a);
            buf.as_mut_slice().copy_from_slice(&data[a..b]);
            ep.send(next, ChunkMsg { idx: send_idx, buf: WireBuf::Excl(buf) });
        }
        let recv_idx = (me + 2 * n - 1 - s) % n;
        let (a, b) = chunk_bound(len, n, recv_idx);
        if b > a {
            let msg = ep.recv(prev);
            debug_assert_eq!(msg.idx, recv_idx, "ring step out of order");
            for (dst, src) in data[a..b].iter_mut().zip(msg.buf.as_slice()) {
                *dst += src;
            }
            // msg drops here — its buffer shelves on THIS thread's arena
        }
    }
    // Phase 2: all-gather the completed chunks around the ring.
    for s in 0..n - 1 {
        let send_idx = (me + 1 + n - s) % n;
        let (a, b) = chunk_bound(len, n, send_idx);
        if b > a {
            let mut buf = ArenaPool::checkout(b - a);
            buf.as_mut_slice().copy_from_slice(&data[a..b]);
            ep.send(next, ChunkMsg { idx: send_idx, buf: WireBuf::Excl(buf) });
        }
        let recv_idx = (me + 2 * n - s) % n;
        let (a, b) = chunk_bound(len, n, recv_idx);
        if b > a {
            let msg = ep.recv(prev);
            debug_assert_eq!(msg.idx, recv_idx, "ring step out of order");
            data[a..b].copy_from_slice(msg.buf.as_slice());
        }
    }
    t
}

/// Broadcast `t` from `root` to all of `group`. Non-roots pass `None`.
/// The payload crosses every edge as one `Arc`-shared buffer — no
/// per-receiver clone — and receivers get a zero-copy shared tensor.
///
/// The wire carries no shape metadata, so the result is a flat `[len]`
/// tensor on **every** rank (root included) — callers reattach shape
/// context, exactly as with the previous `Vec<f32>` return.
pub fn broadcast(ep: &Endpoint<ChunkMsg>, group: &[usize], root: usize, t: Option<Tensor>) -> Tensor {
    if group.len() <= 1 {
        let t = t.expect("root must provide tensor");
        let len = t.len();
        return t.reshape(&[len]);
    }
    if ep.rank == root {
        let t = t.expect("root must provide tensor");
        let len = t.len();
        let t = t.reshape(&[len]).into_shared();
        let arc = t.shared_full_arc().expect("into_shared yields a full-range shared buffer");
        for &r in group {
            if r != root {
                ep.send(r, ChunkMsg { idx: 0, buf: WireBuf::Shared(arc.clone()) });
            }
        }
        t
    } else {
        let msg = ep.recv(root);
        match msg.buf {
            WireBuf::Shared(a) => {
                let len = a.len();
                Tensor::from_storage(&[len], Storage::Shared { buf: a, off: 0, len })
            }
            WireBuf::Excl(b) => {
                let len = b.len();
                Tensor::from_storage(&[len], Storage::Exclusive(b))
            }
        }
    }
}

/// Allocating reference implementations — the pre-arena code paths, kept
/// verbatim (fresh `Vec` per chunk per step, one payload clone per
/// broadcast receiver, empty chunks still round-trip). Used by the
/// differential tests in `tests/zero_copy.rs` and the before/after
/// comparison in `benches/hotpath.rs`.
pub mod reference {
    use super::*;

    pub fn ring_allreduce(ep: &Endpoint<ChunkMsg>, group: &[usize], mut t: Tensor) -> Tensor {
        let n = group.len();
        if n <= 1 {
            return t;
        }
        let me = group.iter().position(|&r| r == ep.rank).expect("rank not in group");
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];
        let len = t.len();
        let data: &mut [f32] = &mut t.data;
        for s in 0..n - 1 {
            let send_idx = (me + n - s) % n;
            let (a, b) = chunk_bound(len, n, send_idx);
            let buf = ArenaBuf::owned(data[a..b].to_vec()); // fresh alloc per chunk
            ep.send(next, ChunkMsg { idx: send_idx, buf: WireBuf::Excl(buf) });
            let msg = ep.recv(prev);
            let (a, b) = chunk_bound(len, n, msg.idx);
            for (dst, src) in data[a..b].iter_mut().zip(msg.buf.as_slice()) {
                *dst += src;
            }
        }
        for s in 0..n - 1 {
            let send_idx = (me + 1 + n - s) % n;
            let (a, b) = chunk_bound(len, n, send_idx);
            let buf = ArenaBuf::owned(data[a..b].to_vec());
            ep.send(next, ChunkMsg { idx: send_idx, buf: WireBuf::Excl(buf) });
            let msg = ep.recv(prev);
            let (a, b) = chunk_bound(len, n, msg.idx);
            data[a..b].copy_from_slice(msg.buf.as_slice());
        }
        t
    }

    pub fn broadcast(
        ep: &Endpoint<ChunkMsg>,
        group: &[usize],
        root: usize,
        t: Option<Tensor>,
    ) -> Tensor {
        if group.len() <= 1 {
            let t = t.expect("root must provide tensor");
            let len = t.len();
            return t.reshape(&[len]);
        }
        if ep.rank == root {
            let t = t.expect("root must provide tensor");
            let len = t.len();
            for &r in group {
                if r != root {
                    // one full payload clone per receiver
                    let buf = ArenaBuf::owned(t.data.to_vec());
                    ep.send(r, ChunkMsg { idx: 0, buf: WireBuf::Excl(buf) });
                }
            }
            t.reshape(&[len])
        } else {
            let msg = ep.recv(root);
            let len = msg.buf.as_slice().len();
            match msg.buf {
                WireBuf::Excl(b) => Tensor::from_storage(&[len], Storage::Exclusive(b)),
                WireBuf::Shared(a) => Tensor::new(&[len], a.as_slice().to_vec()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::channel::{CommWorld, Mode};
    use std::thread;

    fn run_allreduce(n: usize, len: usize) {
        let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
        let group: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let rank = ep.rank;
                    let t = Tensor::new(&[len], (0..len).map(|i| (i + rank) as f32).collect());
                    ring_allreduce(&ep, &group, t)
                })
            })
            .collect();
        let results: Vec<Tensor> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // expected: sum over ranks of (i + rank) = n*i + n(n-1)/2
        let expect: Vec<f32> = (0..len).map(|i| (n * i + n * (n - 1) / 2) as f32).collect();
        for r in &results {
            assert_eq!(r.data, expect);
        }
    }

    #[test]
    fn allreduce_2_ranks() {
        run_allreduce(2, 17);
    }

    #[test]
    fn allreduce_4_ranks() {
        run_allreduce(4, 64);
    }

    #[test]
    fn allreduce_uneven_chunks() {
        run_allreduce(3, 10); // 10 not divisible by 3
    }

    #[test]
    fn allreduce_single_rank_identity() {
        let eps = CommWorld::new::<ChunkMsg>(1, Mode::NonBlocking);
        let t = Tensor::new(&[4], vec![1., 2., 3., 4.]);
        let out = ring_allreduce(&eps[0], &[0], t.clone());
        assert_eq!(out, t);
    }

    #[test]
    fn allreduce_len_smaller_than_group() {
        run_allreduce(4, 2); // some chunks are empty — skipped, not sent
    }

    #[test]
    fn allreduce_len_one() {
        run_allreduce(3, 1); // only one non-empty chunk in the whole ring
    }

    #[test]
    fn empty_chunks_never_hit_the_wire() {
        // len 2, n 4: chunks 2 and 3 are empty. Run the ring, then verify
        // no stray message is left anywhere and nothing was sent for the
        // empty chunks (a leftover empty send would desync the next call).
        let n = 4;
        let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
        let group: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let t = Tensor::new(&[2], vec![ep.rank as f32; 2]);
                    // two back-to-back calls must not desync
                    let t = ring_allreduce(&ep, &group, t);
                    let t = ring_allreduce(&ep, &group, t);
                    for peer in 0..group.len() {
                        if peer != ep.rank {
                            assert!(ep.try_recv(peer).is_none(), "stray message on the wire");
                        }
                    }
                    t
                })
            })
            .collect();
        let expect = vec![(0 + 1 + 2 + 3) as f32 * n as f32; 2];
        for h in handles {
            assert_eq!(h.join().unwrap().data, expect);
        }
    }

    #[test]
    fn broadcast_delivers_to_all() {
        let n = 3;
        let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
        let group: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let t = if ep.rank == 0 {
                        Some(Tensor::new(&[3], vec![7., 8., 9.]))
                    } else {
                        None
                    };
                    broadcast(&ep, &group, 0, t)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().data, vec![7., 8., 9.]);
        }
    }

    #[test]
    fn broadcast_shares_one_buffer_across_three_receivers() {
        // ≥3 receivers: every receiver must see the payload, and all of
        // them alias the SAME shared buffer (no per-receiver copy).
        let n = 4;
        let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
        let group: Vec<usize> = (0..n).collect();
        let payload: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                let payload = payload.clone();
                thread::spawn(move || {
                    let t = (ep.rank == 1).then(|| Tensor::new(&[1000], payload));
                    let out = broadcast(&ep, &group, 1, t);
                    (ep.rank, out.data.as_ptr() as usize, out)
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let root_ptr = results.iter().find(|(r, _, _)| *r == 1).unwrap().1;
        for (rank, ptr, out) in &results {
            assert_eq!(out.data, payload, "rank {rank} got wrong payload");
            assert_eq!(*ptr, root_ptr, "rank {rank} received a copy, not the shared buffer");
        }
    }

    #[test]
    fn allreduce_requires_buffered_channels() {
        // A ring where every rank sends before receiving deadlocks on pure
        // rendezvous channels — the classic reason blocking send/recv (the
        // FT style, §5.4) needs careful ordering. The TP orchestrator
        // therefore always runs collectives on buffered channels; blocking
        // mode only applies to pipeline stage-to-stage sends. This test
        // pins the buffered behaviour.
        let eps = CommWorld::new::<ChunkMsg>(2, Mode::NonBlocking);
        let group = vec![0, 1];
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let t = Tensor::new(&[4], vec![ep.rank as f32; 4]);
                    ring_allreduce(&ep, &group, t)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().data, vec![1.0; 4]);
        }
    }
}
