//! Collectives over [`channel`](super::channel): ring all-reduce and
//! broadcast. These carry real tensor data between TP workers — the SPMD
//! "distributed operations" of the paper's distributed runtime (§4.1.1).
//!
//! The ring all-reduce is the textbook 2(n-1)-step algorithm: n-1
//! reduce-scatter steps followed by n-1 all-gather steps over equal chunks,
//! which is also the cost model `topology::allreduce_time` assumes.

use super::channel::Endpoint;
use crate::tensor::Tensor;

/// Message payload for collectives.
pub type ChunkMsg = (usize, Vec<f32>); // (chunk index, data)

/// Chunk boundaries: n near-equal pieces of `len`.
fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((start, start + sz));
        start += sz;
    }
    out
}

/// Ring all-reduce (sum) across `group` (world ranks, including our own).
/// Every member calls this with its local partial; all return the sum.
///
/// `ep` is this worker's endpoint; `group` must list ranks in the same
/// order on every participant.
pub fn ring_allreduce(ep: &Endpoint<ChunkMsg>, group: &[usize], mut t: Tensor) -> Tensor {
    let n = group.len();
    if n <= 1 {
        return t;
    }
    // (§Perf note: a whole-tensor exchange fast path for n=2 was tried and
    // measured ~35% SLOWER than the ring on this testbed — the ring's two
    // half-size messages pipeline better with the single-core scheduler —
    // so the generic ring is kept for all group sizes. See EXPERIMENTS.md.)
    let me = group.iter().position(|&r| r == ep.rank).expect("rank not in group");
    let next = group[(me + 1) % n];
    let prev = group[(me + n - 1) % n];
    let bounds = chunk_bounds(t.len(), n);

    // Phase 1: reduce-scatter. After step s, rank me owns the full sum of
    // chunk (me + 1) mod n ... converging so chunk (me+1)%n is complete.
    for s in 0..n - 1 {
        let send_idx = (me + n - s) % n;
        let (a, b) = bounds[send_idx];
        ep.send(next, (send_idx, t.data[a..b].to_vec()));
        let (idx, data) = ep.recv(prev);
        let (a, b) = bounds[idx];
        for (dst, src) in t.data[a..b].iter_mut().zip(&data) {
            *dst += src;
        }
    }
    // Phase 2: all-gather the completed chunks around the ring.
    for s in 0..n - 1 {
        let send_idx = (me + 1 + n - s) % n;
        let (a, b) = bounds[send_idx];
        ep.send(next, (send_idx, t.data[a..b].to_vec()));
        let (idx, data) = ep.recv(prev);
        let (a, b) = bounds[idx];
        t.data[a..b].copy_from_slice(&data);
    }
    t
}

/// Broadcast `t` from `root` to all of `group`. Non-roots pass `None`.
pub fn broadcast(ep: &Endpoint<ChunkMsg>, group: &[usize], root: usize, t: Option<Tensor>) -> Vec<f32> {
    if group.len() <= 1 {
        return t.expect("root must provide tensor").data;
    }
    if ep.rank == root {
        let t = t.expect("root must provide tensor");
        for &r in group {
            if r != root {
                ep.send(r, (0, t.data.clone()));
            }
        }
        t.data
    } else {
        ep.recv(root).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::channel::{CommWorld, Mode};
    use std::thread;

    fn run_allreduce(n: usize, len: usize) {
        let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
        let group: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let rank = ep.rank;
                    let t = Tensor::new(&[len], (0..len).map(|i| (i + rank) as f32).collect());
                    ring_allreduce(&ep, &group, t)
                })
            })
            .collect();
        let results: Vec<Tensor> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // expected: sum over ranks of (i + rank) = n*i + n(n-1)/2
        let expect: Vec<f32> = (0..len).map(|i| (n * i + n * (n - 1) / 2) as f32).collect();
        for r in &results {
            assert_eq!(r.data, expect);
        }
    }

    #[test]
    fn allreduce_2_ranks() {
        run_allreduce(2, 17);
    }

    #[test]
    fn allreduce_4_ranks() {
        run_allreduce(4, 64);
    }

    #[test]
    fn allreduce_uneven_chunks() {
        run_allreduce(3, 10); // 10 not divisible by 3
    }

    #[test]
    fn allreduce_single_rank_identity() {
        let eps = CommWorld::new::<ChunkMsg>(1, Mode::NonBlocking);
        let t = Tensor::new(&[4], vec![1., 2., 3., 4.]);
        let out = ring_allreduce(&eps[0], &[0], t.clone());
        assert_eq!(out, t);
    }

    #[test]
    fn allreduce_len_smaller_than_group() {
        run_allreduce(4, 2); // some chunks are empty
    }

    #[test]
    fn broadcast_delivers_to_all() {
        let n = 3;
        let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
        let group: Vec<usize> = (0..n).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let t = if ep.rank == 0 {
                        Some(Tensor::new(&[3], vec![7., 8., 9.]))
                    } else {
                        None
                    };
                    broadcast(&ep, &group, 0, t)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![7., 8., 9.]);
        }
    }

    #[test]
    fn allreduce_requires_buffered_channels() {
        // A ring where every rank sends before receiving deadlocks on pure
        // rendezvous channels — the classic reason blocking send/recv (the
        // FT style, §5.4) needs careful ordering. The TP orchestrator
        // therefore always runs collectives on buffered channels; blocking
        // mode only applies to pipeline stage-to-stage sends. This test
        // pins the buffered behaviour.
        let eps = CommWorld::new::<ChunkMsg>(2, Mode::NonBlocking);
        let group = vec![0, 1];
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let group = group.clone();
                thread::spawn(move || {
                    let t = Tensor::new(&[4], vec![ep.rank as f32; 4]);
                    ring_allreduce(&ep, &group, t)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().data, vec![1.0; 4]);
        }
    }
}
