//! In-process point-to-point channels between workers — the substrate the
//! paper's two communication styles are built on:
//!
//! * **Blocking** (rendezvous): `send` does not return until the peer has
//!   arrived at the matching `recv`. This is FasterTransformer's
//!   `nccl_send`/`nccl_recv` behaviour that §5.4 blames for pipeline
//!   bubbles — the sender's compute stream stalls on a late consumer.
//! * **Non-blocking** (buffered): `send` enqueues and returns immediately;
//!   consecutive devices decouple, which is what NBPP needs (§4.2).
//!
//! One `CommWorld` is created per launch; each worker thread takes its
//! [`Endpoint`]. Endpoints hold a dedicated channel per peer so `recv(from)`
//! is selective (no cross-peer head-of-line blocking).

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::Duration;

/// Channel semantics for the world.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Rendezvous: capacity-0 channels (FasterTransformer baseline).
    Blocking,
    /// Buffered: `send` returns immediately up to the buffer cap (NBPP).
    NonBlocking,
}

/// Buffered capacity for non-blocking channels: deep enough that a pipeline
/// stage never stalls on send in practice, small enough to bound memory.
const NONBLOCKING_CAP: usize = 64;

/// One worker's view of the world: senders to every peer, a receiver from
/// every peer.
pub struct Endpoint<T> {
    pub rank: usize,
    pub world: usize,
    senders: Vec<Option<SyncSender<T>>>,
    receivers: Vec<Option<Receiver<T>>>,
}

/// Builder for a fully-connected world of `n` endpoints.
pub struct CommWorld;

impl CommWorld {
    pub fn new<T: Send>(n: usize, mode: Mode) -> Vec<Endpoint<T>> {
        let cap = match mode {
            Mode::Blocking => 0,
            Mode::NonBlocking => NONBLOCKING_CAP,
        };
        // channels[i][j] carries i -> j
        let mut senders: Vec<Vec<Option<SyncSender<T>>>> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<T>>>> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let (tx, rx) = std::sync::mpsc::sync_channel(cap);
                senders[i][j] = Some(tx);
                receivers[j][i] = Some(rx);
            }
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (s, r))| Endpoint { rank, world: n, senders: s, receivers: r })
            .collect()
    }

    /// Like [`CommWorld::new`] but every rank also gets a channel to itself.
    /// Self-channels are always buffered — a rendezvous self-send would
    /// deadlock — so loops work even in a [`Mode::Blocking`] world. Used by
    /// meshes whose topology can map a rank onto itself (the peer-memory
    /// ring with world size 1).
    pub fn new_looped<T: Send>(n: usize, mode: Mode) -> Vec<Endpoint<T>> {
        let mut eps = CommWorld::new::<T>(n, mode);
        for (rank, ep) in eps.iter_mut().enumerate() {
            let (tx, rx) = std::sync::mpsc::sync_channel(NONBLOCKING_CAP);
            ep.senders[rank] = Some(tx);
            ep.receivers[rank] = Some(rx);
        }
        eps
    }
}

impl<T: Send> Endpoint<T> {
    /// Send to `peer`. Blocks per the world's [`Mode`] (rendezvous vs
    /// buffered). Panics if the peer endpoint was dropped — that is a
    /// worker crash, which the engine surfaces as a failed batch.
    pub fn send(&self, peer: usize, msg: T) {
        self.senders[peer]
            .as_ref()
            .expect("no self-send")
            .send(msg)
            .unwrap_or_else(|_| panic!("worker {peer} hung up (send from {})", self.rank));
    }

    /// Non-blocking best-effort send. Returns the message back on a full
    /// buffer (backpressure signal for the batcher).
    pub fn try_send(&self, peer: usize, msg: T) -> Result<(), T> {
        match self.senders[peer].as_ref().expect("no self-send").try_send(msg) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(m)) | Err(TrySendError::Disconnected(m)) => Err(m),
        }
    }

    /// Receive from a specific peer, blocking.
    pub fn recv(&self, peer: usize) -> T {
        self.receivers[peer]
            .as_ref()
            .expect("no self-recv")
            .recv()
            .unwrap_or_else(|_| panic!("worker {peer} hung up (recv at {})", self.rank))
    }

    /// Receive with a timeout — deadlock detection in tests and the engine
    /// watchdog.
    pub fn recv_timeout(&self, peer: usize, timeout: Duration) -> Result<T, RecvTimeoutError> {
        self.receivers[peer].as_ref().expect("no self-recv").recv_timeout(timeout)
    }

    pub fn try_recv(&self, peer: usize) -> Option<T> {
        self.receivers[peer].as_ref().and_then(|r| r.try_recv().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn pingpong_nonblocking() {
        let mut eps = CommWorld::new::<u64>(2, Mode::NonBlocking);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            let v = e1.recv(0);
            e1.send(0, v + 1);
        });
        e0.send(1, 41);
        assert_eq!(e0.recv(1), 42);
        h.join().unwrap();
    }

    #[test]
    fn nonblocking_send_returns_before_recv() {
        let mut eps = CommWorld::new::<u64>(2, Mode::NonBlocking);
        let _e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        // no receiver running: buffered send must not block
        e0.send(1, 7);
        e0.send(1, 8);
    }

    #[test]
    fn blocking_send_rendezvous() {
        let mut eps = CommWorld::new::<u64>(2, Mode::Blocking);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let sent = Arc::new(AtomicBool::new(false));
        let sent2 = sent.clone();
        let h = thread::spawn(move || {
            e0.send(1, 1); // must block until e1 recvs
            sent2.store(true, Ordering::SeqCst);
        });
        thread::sleep(Duration::from_millis(50));
        assert!(!sent.load(Ordering::SeqCst), "blocking send returned early");
        assert_eq!(e1.recv(0), 1);
        h.join().unwrap();
        assert!(sent.load(Ordering::SeqCst));
    }

    #[test]
    fn selective_recv_by_peer() {
        let mut eps = CommWorld::new::<&'static str>(3, Mode::NonBlocking);
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e1.send(2, "from1");
        e0.send(2, "from0");
        // selective: ask for peer 1 first even though 0 arrived too
        assert_eq!(e2.recv(1), "from1");
        assert_eq!(e2.recv(0), "from0");
    }

    #[test]
    fn try_recv_and_timeout() {
        let mut eps = CommWorld::new::<u64>(2, Mode::NonBlocking);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        assert!(e1.try_recv(0).is_none());
        assert!(e1.recv_timeout(0, Duration::from_millis(10)).is_err());
        e0.send(1, 5);
        assert_eq!(e1.try_recv(0), Some(5));
    }

    #[test]
    fn looped_world_allows_self_send() {
        // even in a Blocking world the self-channel is buffered
        let mut eps = CommWorld::new_looped::<u64>(1, Mode::Blocking);
        let e0 = eps.pop().unwrap();
        e0.send(0, 13);
        e0.send(0, 14);
        assert_eq!(e0.recv(0), 13);
        assert_eq!(e0.try_recv(0), Some(14));
        assert!(e0.try_recv(0).is_none());
        // cross-rank channels still behave per the mode
        let mut eps = CommWorld::new_looped::<u64>(2, Mode::NonBlocking);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 7);
        e0.send(0, 8);
        assert_eq!(e1.recv(0), 7);
        assert_eq!(e0.recv(0), 8);
    }

    #[test]
    fn try_send_backpressure() {
        let mut eps = CommWorld::new::<u64>(2, Mode::Blocking);
        let _e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        // rendezvous channel with no receiver: try_send must bounce
        assert!(e0.try_send(1, 9).is_err());
    }
}
