//! Interconnect topology + analytic transfer-cost model.
//!
//! Mirrors the paper's two testbeds (§5.1): a fully NVLink-connected
//! 8×A100 server and a partially connected one where only GPU pairs share
//! NVLink and everything else crosses PCIe. Host memory hangs off a
//! PCIe×CPU link (the BMInf offload path, §5.6).

/// A point-to-point link: bandwidth + fixed per-message latency.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub bandwidth_gbps: f64, // GB/s (10^9 bytes)
    pub latency_us: f64,     // fixed overhead per transfer
}

impl Link {
    pub const NVLINK: Link = Link { bandwidth_gbps: 600.0, latency_us: 5.0 };
    pub const PCIE4: Link = Link { bandwidth_gbps: 32.0, latency_us: 10.0 };
    /// CPU<->GPU effective copy bandwidth (pinned-memory PCIe, §4.4).
    pub const HOST: Link = Link { bandwidth_gbps: 25.0, latency_us: 15.0 };

    /// Seconds to move `bytes` across this link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_us * 1e-6 + bytes as f64 / (self.bandwidth_gbps * 1e9)
    }
}

/// Inter-device wiring of one node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Interconnect {
    /// All pairs via NVSwitch (paper's first server).
    FullNvlink,
    /// GPUs 2k/2k+1 share NVLink; other pairs cross PCIe (second server).
    PairedNvlink,
    /// Everything over PCIe (worst case, used in ablations).
    Pcie,
}

/// A node: `n_devices` accelerators + host memory.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n_devices: usize,
    pub interconnect: Interconnect,
    pub host_link: Link,
}

impl Topology {
    pub fn new(n_devices: usize, interconnect: Interconnect) -> Topology {
        Topology { n_devices, interconnect, host_link: Link::HOST }
    }

    /// Paper server 1: 8×A100 fully NVLink connected.
    pub fn full_nvlink(n: usize) -> Topology {
        Topology::new(n, Interconnect::FullNvlink)
    }

    /// Paper server 2: 8×A100, every two GPUs share NVLink.
    pub fn paired_nvlink(n: usize) -> Topology {
        Topology::new(n, Interconnect::PairedNvlink)
    }

    /// The device↔device link.
    pub fn link(&self, a: usize, b: usize) -> Link {
        assert!(a < self.n_devices && b < self.n_devices && a != b);
        match self.interconnect {
            Interconnect::FullNvlink => Link::NVLINK,
            Interconnect::PairedNvlink => {
                if a / 2 == b / 2 {
                    Link::NVLINK
                } else {
                    Link::PCIE4
                }
            }
            Interconnect::Pcie => Link::PCIE4,
        }
    }

    /// Slowest link among a group — the ring all-reduce bottleneck.
    pub fn bottleneck(&self, ranks: &[usize]) -> Link {
        let mut worst = Link::NVLINK;
        for i in 0..ranks.len() {
            let j = (i + 1) % ranks.len();
            if ranks[i] == ranks[j] {
                continue;
            }
            let l = self.link(ranks[i], ranks[j]);
            if l.bandwidth_gbps < worst.bandwidth_gbps {
                worst = l;
            }
        }
        worst
    }

    /// Point-to-point transfer time in seconds.
    pub fn p2p_time(&self, a: usize, b: usize, bytes: u64) -> f64 {
        self.link(a, b).transfer_time(bytes)
    }

    /// Host↔device copy time in seconds (PMEP's CPU fallback, BMInf path).
    pub fn host_time(&self, bytes: u64) -> f64 {
        self.host_link.transfer_time(bytes)
    }

    /// Ring all-reduce over `ranks`: 2(n-1)/n · bytes over the bottleneck
    /// link plus 2(n-1) latency hops (standard ring cost model).
    pub fn allreduce_time(&self, ranks: &[usize], bytes: u64) -> f64 {
        let n = ranks.len();
        if n <= 1 {
            return 0.0;
        }
        let link = self.bottleneck(ranks);
        let steps = 2 * (n - 1);
        let volume = 2.0 * (n - 1) as f64 / n as f64 * bytes as f64;
        steps as f64 * link.latency_us * 1e-6 + volume / (link.bandwidth_gbps * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_nvlink_is_uniform() {
        let t = Topology::full_nvlink(8);
        for a in 0..8 {
            for b in 0..8 {
                if a != b {
                    assert_eq!(t.link(a, b), Link::NVLINK);
                }
            }
        }
    }

    #[test]
    fn paired_topology_matches_paper_server2() {
        let t = Topology::paired_nvlink(8);
        assert_eq!(t.link(0, 1), Link::NVLINK);
        assert_eq!(t.link(2, 3), Link::NVLINK);
        assert_eq!(t.link(1, 2), Link::PCIE4);
        assert_eq!(t.link(0, 7), Link::PCIE4);
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let l = Link::NVLINK;
        let t1 = l.transfer_time(600_000_000); // 0.6 GB -> ~1 ms + lat
        assert!((t1 - (5e-6 + 0.001)).abs() < 1e-9);
    }

    #[test]
    fn gpt3_layer_offload_matches_paper_estimate() {
        // §4.4: one GPT3-175B layer = 3.375 GB fp16 over NVLink ≈ 5.63 ms
        let bytes = (3.375 * 1024.0 * 1024.0 * 1024.0) as u64;
        let t = Link::NVLINK.transfer_time(bytes);
        assert!((t - 5.63e-3).abs() < 0.5e-3, "t={t}");
    }

    #[test]
    fn allreduce_cost_monotonic_in_group() {
        let t = Topology::full_nvlink(8);
        let b = 64 * 1024 * 1024;
        let t2 = t.allreduce_time(&[0, 1], b);
        let t8 = t.allreduce_time(&[0, 1, 2, 3, 4, 5, 6, 7], b);
        assert!(t8 > t2);
        assert_eq!(t.allreduce_time(&[0], b), 0.0);
    }

    #[test]
    fn paired_allreduce_bottlenecked_by_pcie() {
        let t = Topology::paired_nvlink(4);
        let b = 64 * 1024 * 1024;
        let within_pair = Topology::full_nvlink(4).allreduce_time(&[0, 1], b);
        let across = t.allreduce_time(&[0, 1, 2, 3], b);
        // crossing PCIe must dominate: >10x slower per §5.5's observation
        assert!(across > 5.0 * within_pair, "{across} vs {within_pair}");
    }
}
