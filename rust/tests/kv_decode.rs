//! Incremental decode vs. the legacy re-prefill path, differentially.
//!
//! The KV-cache path (prefill seeds per-worker paged caches; continuation
//! steps run one position against them) must emit exactly the token
//! streams the re-prefill path emits — greedy decoding is deterministic,
//! so any divergence is a cache-management bug. Checked across tp=1/tp=2,
//! stop-token early exit, and sessions that run into the context limit;
//! plus engine-level checks that finished sessions return their blocks.

use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
use energonai::memory::kvcache;
use std::sync::Mutex;

/// Serializes every test in this binary: two of them assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    // a poisoned lock just means another test failed; the counters are
    // still coherent
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn launch(kv: bool, tp: usize) -> Engine {
    Engine::launch(
        LaunchConfig::preset("tiny")
            .with_parallel(tp, 1)
            .with_kv_cache(kv),
    )
    .unwrap()
}

fn prompts() -> Vec<Vec<i32>> {
    (0..5)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// The acceptance bar: cached incremental decode produces byte-identical
/// token streams to the legacy path, sequentially and concurrently.
fn assert_parity(tp: usize) {
    let _guard = stats_guard();
    let legacy = launch(false, tp);
    assert!(!legacy.kv_cache_on(), "kv_cache(false) must disable decode");
    let expect: Vec<Vec<i32>> = prompts()
        .into_iter()
        .map(|p| legacy.generate(p, 8).unwrap())
        .collect();
    legacy.shutdown();

    let cached = launch(true, tp);
    assert!(
        cached.kv_cache_on(),
        "decode artifacts missing for tp={tp}; re-run `make artifacts`"
    );
    // sequential sessions
    let got: Vec<Vec<i32>> = prompts()
        .into_iter()
        .map(|p| cached.generate(p, 8).unwrap())
        .collect();
    assert_eq!(got, expect, "cached decode diverged (sequential, tp={tp})");
    // concurrent sessions: decode buckets coalesce and must still agree
    let grefs: Vec<_> = prompts()
        .into_iter()
        .map(|p| cached.generate_stream(GenRequest::new(p, 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "cached decode diverged (concurrent, tp={tp})");
    cached.shutdown();
}

#[test]
fn cached_decode_matches_reprefill_tp1() {
    assert_parity(1);
}

#[test]
fn cached_decode_matches_reprefill_tp2() {
    assert_parity(2);
}

/// Stop-token early exit: identical truncation on both paths, and the
/// stopped session's blocks are freed.
#[test]
fn stop_token_parity() {
    let _guard = stats_guard();
    let legacy = launch(false, 1);
    let prompt = vec![5, 9, 2];
    let free_run = legacy.generate(prompt.clone(), 6).unwrap();
    assert!(free_run.len() > prompt.len() + 1);
    let stop = free_run[prompt.len() + 1];
    let expect = legacy
        .generate_stream(GenRequest::new(prompt.clone(), 6).with_stop(stop))
        .unwrap()
        .to_here()
        .unwrap();
    legacy.shutdown();

    let cached = launch(true, 1);
    let got = cached
        .generate_stream(GenRequest::new(prompt.clone(), 6).with_stop(stop))
        .unwrap()
        .to_here()
        .unwrap();
    assert_eq!(got, expect, "stop-token truncation diverged");
    assert_eq!(*got.last().unwrap(), stop);
    cached.shutdown();
}

/// Sessions that run into the longest compiled bucket (tiny: 32) end at
/// the same point on both paths — the cache capacity equals max_seq, so
/// the limit must come from the scheduler, not a cache overflow.
#[test]
fn max_length_session_parity() {
    let _guard = stats_guard();
    let legacy = launch(false, 1);
    let prompt: Vec<i32> = (1..=28).collect();
    let expect = legacy.generate(prompt.clone(), 16).unwrap();
    legacy.shutdown();
    // 28 + 16 > 32: the session must stop early at the context limit
    assert!(expect.len() < 28 + 16, "context limit never hit");

    let cached = launch(true, 1);
    let got = cached.generate(prompt, 16).unwrap();
    assert_eq!(got, expect, "context-limit truncation diverged");
    cached.shutdown();
}

/// Engine-level no-leak: after every session completes and the engine
/// drains, all cache blocks are back on the free lists.
#[test]
fn finished_sessions_release_their_blocks() {
    let _guard = stats_guard();
    let before = kvcache::global_stats().blocks_in_use;
    let engine = launch(true, 1);
    let grefs: Vec<_> = prompts()
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, 6)).unwrap())
        .collect();
    for g in &grefs {
        g.to_here().unwrap();
    }
    let m = engine.metrics_snapshot();
    assert!(m.kvcache_stats().blocks_peak > 0, "cache never used: {}", m.summary());
    engine.shutdown(); // drains sessions; ticketed releases ran before workers exited
    let after = kvcache::global_stats().blocks_in_use;
    assert_eq!(after, before, "cache blocks leaked across the engine lifetime");
}

/// Re-used engine serves many session waves without growing the slab
/// beyond the first wave's peak (block recycling at the engine level).
#[test]
fn sequential_waves_recycle_blocks() {
    let _guard = stats_guard();
    let engine = launch(true, 1);
    let mut peak_after_first = 0;
    for wave in 0..5 {
        for p in prompts() {
            engine.generate(p, 4).unwrap();
        }
        let grown = kvcache::global_stats().blocks_grown;
        if wave == 0 {
            peak_after_first = grown;
        } else {
            assert_eq!(
                grown, peak_after_first,
                "wave {wave} grew the slab instead of recycling"
            );
        }
    }
    let m = engine.metrics_snapshot();
    assert!(m.kvcache_stats().blocks_recycled > 0, "{}", m.summary());
    engine.shutdown();
}
