//! Tiered KV cache, differentially: spilling cold sessions to the host
//! tier and prefetching them on re-entry must be invisible in the token
//! streams (greedy decoding is deterministic, so any divergence is a
//! tiering bug) while letting a device slab sized for K sessions serve
//! many more concurrent sessions than K.
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::engine::{Engine, GenRequest, GenRef, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use std::sync::Mutex;

/// Serializes every test in this binary: several assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Decode artifacts for (tiny, tp) present? When not, the test is a
/// no-op — matching the seed state instead of adding failures.
fn artifacts_ready(tp: usize) -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", tp).is_empty() && man.has_kv_prefill("tiny", tp);
    if !ok {
        eprintln!("skipping: decode artifacts missing for tiny/tp{tp}");
    }
    ok
}

/// A spill-enabled engine with a deliberately tiny device tier:
/// `device_blocks` blocks per worker, unlimited host tier. Two dispatcher
/// threads bound the number of pinned (in-flight) sessions.
fn launch_spill(tp: usize, device_blocks: usize) -> Engine {
    let mut lc = LaunchConfig::preset("tiny")
        .with_parallel(tp, 1)
        .with_kv_spill(device_blocks, 0);
    lc.engine.pool_threads = 2;
    Engine::launch(lc).unwrap()
}

fn launch_resident(tp: usize) -> Engine {
    Engine::launch(LaunchConfig::preset("tiny").with_parallel(tp, 1)).unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// The tentpole acceptance bar: with a device tier sized for ~K sessions,
/// 3K+ concurrent sessions all complete, spill/prefetch counters move,
/// and every token stream is byte-identical to the resident-only run.
fn assert_spill_parity(tp: usize, n_sessions: usize, device_blocks: usize) {
    if !artifacts_ready(tp) {
        return;
    }
    let _guard = stats_guard();

    let resident = launch_resident(tp);
    assert!(resident.kv_cache_on(), "decode artifacts present but cache off");
    assert!(!resident.kv_spill_on());
    let expect: Vec<Vec<i32>> = prompts(n_sessions)
        .into_iter()
        .map(|p| resident.generate(p, 8).unwrap())
        .collect();
    resident.shutdown();

    let before = kvcache::global_stats();
    let spilled = launch_spill(tp, device_blocks);
    assert!(spilled.kv_spill_on());
    let grefs: Vec<GenRef> = prompts(n_sessions)
        .into_iter()
        .map(|p| spilled.generate_stream(GenRequest::new(p, 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "tiered decode diverged (tp={tp})");

    let stats = spilled.metrics_snapshot().kvcache_stats();
    assert!(
        stats.spills > before.spills,
        "device tier of {device_blocks} blocks never spilled under {n_sessions} sessions"
    );
    assert!(stats.prefetches > before.prefetches, "spilled sessions never staged back");
    assert_eq!(
        stats.gather_spilled, before.gather_spilled,
        "a decode bucket dispatched against a spilled session"
    );
    spilled.shutdown();
    // everything released from both tiers after the drain
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "device blocks leaked");
    assert_eq!(after.host_bytes, before.host_bytes, "host-tier bytes leaked");
    assert_eq!(after.sessions_spilled, before.sessions_spilled);
}

#[test]
fn tiered_decode_matches_resident_tp1() {
    // tiny prompts run 2..8 tokens -> 9..16 positions -> 1..2 blocks per
    // session. 8 device blocks ≈ 4 sessions; 16 concurrent = 4x that.
    assert_spill_parity(1, 16, 8);
}

#[test]
fn tiered_decode_matches_resident_tp2() {
    assert_spill_parity(2, 16, 8);
}

/// Stop-token early exit with blocks in the host tier: same truncation,
/// and the stopped sessions' blocks leave both tiers.
#[test]
fn stop_token_parity_with_spill() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let resident = launch_resident(1);
    let prompt = vec![5, 9, 2];
    let free_run = resident.generate(prompt.clone(), 6).unwrap();
    assert!(free_run.len() > prompt.len() + 1);
    let stop = free_run[prompt.len() + 1];
    let expect: Vec<Vec<i32>> = (0..8)
        .map(|_| {
            resident
                .generate_stream(GenRequest::new(prompt.clone(), 6).with_stop(stop))
                .unwrap()
                .to_here()
                .unwrap()
        })
        .collect();
    resident.shutdown();

    let before = kvcache::global_stats();
    let spilled = launch_spill(1, 4);
    let grefs: Vec<GenRef> = (0..8)
        .map(|_| {
            spilled
                .generate_stream(GenRequest::new(prompt.clone(), 6).with_stop(stop))
                .unwrap()
        })
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "stop-token truncation diverged under spill");
    for g in &got {
        assert_eq!(*g.last().unwrap(), stop);
    }
    spilled.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "stop-token leaked device blocks");
    assert_eq!(after.host_bytes, before.host_bytes, "stop-token leaked host bytes");
}

/// Sequential waves through a tiny device tier: the slab must not grow
/// beyond its cap (no overflow) and the host tier must fully drain.
#[test]
fn waves_respect_the_device_cap() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let before = kvcache::global_stats();
    let engine = launch_spill(1, 8);
    for _ in 0..3 {
        let grefs: Vec<GenRef> = prompts(12)
            .into_iter()
            .map(|p| engine.generate_stream(GenRequest::new(p, 4)).unwrap())
            .collect();
        for g in &grefs {
            g.to_here().unwrap();
        }
    }
    let stats = engine.metrics_snapshot().kvcache_stats();
    assert_eq!(
        stats.overflow_blocks, before.overflow_blocks,
        "admission control let the device tier overflow"
    );
    assert_eq!(stats.gather_spilled, before.gather_spilled);
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use);
    assert_eq!(after.host_bytes, before.host_bytes);
}
