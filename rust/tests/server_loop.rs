//! Serving front-end integration: the TCP line protocol over a live
//! engine, plus protocol-grammar checks through `handle_line`.

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::server::{handle_line, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn engine() -> Arc<Engine> {
    Arc::new(Engine::launch(LaunchConfig::preset("tiny")).unwrap())
}

#[test]
fn tcp_round_trip_with_concurrent_clients() {
    let engine = engine();
    let server = Server::start(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let clients: Vec<_> = (0..3)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                let mut replies = Vec::new();
                for i in 0..3 {
                    writeln!(writer, "infer {},{},{}", c + 1, i + 1, 7).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    replies.push(line.trim().to_string());
                }
                writeln!(writer, "stats").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                replies.push(line.trim().to_string());
                writeln!(writer, "quit").unwrap();
                replies
            })
        })
        .collect();

    for c in clients {
        let replies = c.join().unwrap();
        assert_eq!(replies.len(), 4);
        for r in &replies[0..3] {
            assert!(r.starts_with("ok "), "bad reply {r:?}");
            let tok: i32 = r[3..].parse().unwrap();
            assert!((0..128).contains(&tok));
        }
        assert!(replies[3].starts_with("ok "), "stats reply {:?}", replies[3]);
    }
    server.stop();
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

#[test]
fn protocol_grammar() {
    let engine = engine();
    // quit closes
    assert!(handle_line("quit", &engine).is_none());
    // unknown command
    let r = handle_line("frobnicate", &engine).unwrap();
    assert!(r.starts_with("err "));
    // malformed token lists
    for bad in ["infer ", "infer a,b", "infer 1,,2"] {
        let r = handle_line(bad, &engine).unwrap();
        assert!(r.starts_with("err "), "{bad:?} -> {r:?}");
    }
    // valid inference
    let r = handle_line("infer 4, 8, 15", &engine).unwrap();
    assert!(r.starts_with("ok "), "{r:?}");
    // stats
    let r = handle_line("stats", &engine).unwrap();
    assert!(r.contains("req/s"), "{r:?}");
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

#[test]
fn request_longer_than_buckets_is_err_not_crash() {
    let engine = engine();
    let long: Vec<String> = (0..200).map(|i| i.to_string()).collect();
    let r = handle_line(&format!("infer {}", long.join(",")), &engine).unwrap();
    assert!(r.starts_with("err "), "{r:?}");
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}
