//! Serving front-end integration: the TCP line protocol over a live
//! engine, plus protocol-grammar checks through `handle_line`.

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::server::{handle_line, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn engine() -> Arc<Engine> {
    Arc::new(Engine::launch(LaunchConfig::preset("tiny")).unwrap())
}

#[test]
fn tcp_round_trip_with_concurrent_clients() {
    let engine = engine();
    let server = Server::start(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr;

    let clients: Vec<_> = (0..3)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                let mut replies = Vec::new();
                for i in 0..3 {
                    writeln!(writer, "infer {},{},{}", c + 1, i + 1, 7).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    replies.push(line.trim().to_string());
                }
                writeln!(writer, "stats").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                replies.push(line.trim().to_string());
                writeln!(writer, "quit").unwrap();
                replies
            })
        })
        .collect();

    for c in clients {
        let replies = c.join().unwrap();
        assert_eq!(replies.len(), 4);
        for r in &replies[0..3] {
            assert!(r.starts_with("ok "), "bad reply {r:?}");
            let tok: i32 = r[3..].parse().unwrap();
            assert!((0..128).contains(&tok));
        }
        assert!(replies[3].starts_with("ok "), "stats reply {:?}", replies[3]);
    }
    server.stop();
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

#[test]
fn tcp_gen_streams_tokens_then_done() {
    let engine = engine();
    let expect = engine.generate(vec![5, 9, 2], 4).unwrap();
    let server = Server::start(engine.clone(), "127.0.0.1:0").unwrap();

    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "gen 4 5,9,2").unwrap();

    let mut toks = Vec::new();
    let done = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim().to_string();
        if let Some(t) = line.strip_prefix("tok ") {
            toks.push(t.parse::<i32>().unwrap());
        } else if let Some(rest) = line.strip_prefix("done ") {
            break rest
                .split(',')
                .map(|t| t.parse::<i32>().unwrap())
                .collect::<Vec<i32>>();
        } else {
            panic!("unexpected stream line {line:?}");
        }
    };
    // streamed tokens are exactly the continuation, and the final line is
    // the full sequence — identical to the in-process generate() result
    assert_eq!(done, expect);
    assert_eq!(toks[..], done[3..]);
    writeln!(writer, "quit").unwrap();

    server.stop();
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

#[test]
fn protocol_grammar() {
    let engine = engine();
    // quit closes
    assert!(handle_line("quit", &engine).is_none());
    // unknown command
    let r = handle_line("frobnicate", &engine).unwrap();
    assert!(r.starts_with("err "));
    // malformed token lists
    for bad in ["infer ", "infer a,b", "infer 1,,2"] {
        let r = handle_line(bad, &engine).unwrap();
        assert!(r.starts_with("err "), "{bad:?} -> {r:?}");
    }
    // malformed gen commands
    for bad in ["gen ", "gen x 1,2", "gen 4", "gen 4 a,b", "gen 0 1,2"] {
        let r = handle_line(bad, &engine).unwrap();
        assert!(r.starts_with("err "), "{bad:?} -> {r:?}");
    }
    // valid inference
    let r = handle_line("infer 4, 8, 15", &engine).unwrap();
    assert!(r.starts_with("ok "), "{r:?}");
    // valid generation (drained form): tok lines then done
    let r = handle_line("gen 3 4, 8, 15", &engine).unwrap();
    assert!(r.starts_with("tok "), "{r:?}");
    assert!(r.lines().last().unwrap().starts_with("done "), "{r:?}");
    assert_eq!(r.lines().filter(|l| l.starts_with("tok ")).count(), 3, "{r:?}");
    // stats
    let r = handle_line("stats", &engine).unwrap();
    assert!(r.contains("req/s"), "{r:?}");
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

#[test]
fn request_longer_than_buckets_is_err_not_crash() {
    let engine = engine();
    let long: Vec<String> = (0..200).map(|i| i.to_string()).collect();
    let r = handle_line(&format!("infer {}", long.join(",")), &engine).unwrap();
    assert!(r.starts_with("err "), "{r:?}");
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}
