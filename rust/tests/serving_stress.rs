//! Serving stress + property-style invariants over the live stack:
//! variable-length heavy-tailed workloads through every engine
//! configuration must (a) complete, (b) return in-vocab tokens, and
//! (c) be deterministic for identical inputs across configurations.

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::coordinator::Request;
use energonai::workload::{Generator, LengthDist};

/// Same request set through different configs → same tokens (the
/// coordinator must be numerically transparent).
#[test]
fn tokens_invariant_across_parallel_configs() {
    let mut gen = Generator::new(99, LengthDist::HeavyTail(16, 1.1), 100);
    let batches: Vec<Vec<Request>> = (0..5).map(|_| gen.batch(2)).collect();

    let run = |launch: LaunchConfig| -> Vec<Vec<i32>> {
        let engine = Engine::launch(launch).unwrap();
        let out = batches
            .iter()
            .map(|reqs| engine.infer_batch(reqs.clone()).unwrap().to_here().unwrap().next_tokens)
            .collect();
        engine.shutdown();
        out
    };

    let expect = run(LaunchConfig::preset("tiny"));
    for (label, launch) in [
        ("tp2", LaunchConfig::preset("tiny").with_parallel(2, 1)),
        ("pp2", LaunchConfig::preset("tiny").with_parallel(1, 2)),
        ("drce", LaunchConfig::preset("tiny").with_drce(true)),
        ("tp2+drce", LaunchConfig::preset("tiny").with_parallel(2, 1).with_drce(true)),
        ("blocking pp2", LaunchConfig::preset("tiny").with_parallel(1, 2).with_blocking_comms(true)),
    ] {
        let got = run(launch);
        assert_eq!(got, expect, "{label} changed greedy tokens");
    }
}

/// Sustained stream through the batcher: everything completes, in vocab.
#[test]
fn sustained_batcher_stream_completes() {
    let engine = Engine::launch(LaunchConfig::preset("tiny").with_parallel(1, 2)).unwrap();
    let mut gen = Generator::new(5, LengthDist::HeavyTail(16, 1.2), engine.cfg.vocab);
    let futures: Vec<_> = (0..60)
        .map(|_| engine.submit(gen.request().tokens).unwrap())
        .collect();
    for (i, f) in futures.iter().enumerate() {
        let tok = f.to_here().unwrap_or_else(|e| panic!("request {i}: {e:#}"));
        assert!((0..128).contains(&tok), "request {i} token {tok}");
    }
    let m = engine.metrics_snapshot();
    assert_eq!(m.requests(), 60);
    // the dynamic batcher must have coalesced (fewer batches than requests)
    assert!(m.batches() < 60, "batching never happened: {}", m.summary());
    engine.shutdown();
}

/// Interleaved direct batches on a TP engine under dispatcher racing:
/// the consistency queue keeps all results correct.
#[test]
fn racing_submitters_with_consistency_queue() {
    let engine = std::sync::Arc::new(
        Engine::launch(LaunchConfig::preset("tiny").with_parallel(2, 1)).unwrap(),
    );
    // oracle per signature
    let sig = |k: u64| vec![Request::new(k, vec![(k % 100) as i32 + 1; 8])];
    let oracle: Vec<Vec<i32>> = (0..4u64)
        .map(|k| engine.infer_batch(sig(k)).unwrap().to_here().unwrap().next_tokens)
        .collect();

    let handles: Vec<_> = (0..4u64)
        .map(|t| {
            let engine = engine.clone();
            std::thread::spawn(move || {
                (0..6)
                    .map(|i| {
                        let k = (t + i) % 4;
                        (k, engine.infer_batch(sig(k)).unwrap())
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    for h in handles {
        for (k, rref) in h.join().unwrap() {
            let out = rref.to_here().unwrap();
            assert_eq!(out.next_tokens, oracle[k as usize], "batch sig {k} corrupted");
        }
    }
    match std::sync::Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

/// Error paths: a worker-refused batch reports, engine survives.
#[test]
fn engine_survives_rejected_batches() {
    let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    for _ in 0..3 {
        assert!(engine.infer_batch(vec![]).is_err());
        assert!(engine
            .infer_batch(vec![Request::new(0, vec![1; 500])])
            .is_err());
    }
    // engine still serves
    let out = engine
        .infer_batch(vec![Request::new(1, vec![1, 2, 3])])
        .unwrap()
        .to_here()
        .unwrap();
    assert_eq!(out.next_tokens.len(), 1);
    engine.shutdown();
}

/// Autoregressive generation: deterministic, grows by n tokens, and the
/// parallel engine generates the identical continuation.
#[test]
fn generation_is_deterministic_and_config_invariant() {
    let serial = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let a = serial.generate(vec![5, 9, 2], 5).unwrap();
    let b = serial.generate(vec![5, 9, 2], 5).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len(), 8);
    assert_eq!(&a[..3], &[5, 9, 2]);
    serial.shutdown();

    let tp2 = Engine::launch(LaunchConfig::preset("tiny").with_parallel(2, 1)).unwrap();
    let c = tp2.generate(vec![5, 9, 2], 5).unwrap();
    assert_eq!(c, a, "tp2 generated a different continuation");
    tp2.shutdown();
}

/// Generation stops at the longest compiled bucket instead of erroring.
#[test]
fn generation_clamps_to_max_bucket() {
    let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let out = engine.generate(vec![1; 30], 10).unwrap();
    assert!(out.len() <= 32, "{}", out.len());
    engine.shutdown();
}
