//! End-to-end engine tests: the full hierarchy-controller stack over real
//! PJRT execution (tiny preset). The key invariant everywhere: any
//! parallel/packed configuration must produce exactly the same logits as
//! the serial engine, because the math is identical — the coordinator only
//! moves it around.

use energonai::coordinator::engine::{Engine, LaunchConfig, MemoryMode};
use energonai::coordinator::Request;
use energonai::memory::pool::PoolConfig;
use energonai::tensor::Tensor;

fn reqs(n: usize, len: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::new(i as u64, (0..len).map(|t| ((i * 31 + t * 7) % 100) as i32 + 1).collect()))
        .collect()
}

fn run_once(launch: LaunchConfig, requests: Vec<Request>) -> Tensor {
    let engine = Engine::launch(launch).unwrap();
    let rref = engine.infer_batch(requests).unwrap();
    let out = rref.to_here().unwrap();
    engine.shutdown();
    out.logits
}

fn serial_reference(requests: Vec<Request>) -> Tensor {
    run_once(LaunchConfig::preset("tiny"), requests)
}

#[test]
fn serial_engine_round_trip() {
    let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let rref = engine.infer_batch(reqs(2, 10)).unwrap();
    let out = rref.to_here().unwrap();
    assert_eq!(out.next_tokens.len(), 2);
    assert_eq!(out.logits.shape, vec![2, 16, 128]);
    assert!(out.logits.data.iter().all(|v| v.is_finite()));
    engine.shutdown();
}

#[test]
fn tp2_matches_serial() {
    let expect = serial_reference(reqs(2, 10));
    let got = run_once(LaunchConfig::preset("tiny").with_parallel(2, 1), reqs(2, 10));
    let diff = got.max_abs_diff(&expect);
    assert!(diff < 2e-2, "tp2 vs serial logits diff {diff}");
}

#[test]
fn pp2_matches_serial() {
    let expect = serial_reference(reqs(2, 10));
    let got = run_once(LaunchConfig::preset("tiny").with_parallel(1, 2), reqs(2, 10));
    let diff = got.max_abs_diff(&expect);
    assert!(diff < 2e-2, "pp2 vs serial logits diff {diff}");
}

#[test]
fn tp2_pp2_matches_serial() {
    let expect = serial_reference(reqs(2, 10));
    let got = run_once(LaunchConfig::preset("tiny").with_parallel(2, 2), reqs(2, 10));
    let diff = got.max_abs_diff(&expect);
    assert!(diff < 2e-2, "tp2pp2 vs serial logits diff {diff}");
}

#[test]
fn drce_matches_padded_on_valid_tokens() {
    // variable lengths: 9 + 5 = 14 valid tokens fit the t=16 bucket
    let requests = vec![
        Request::new(0, (1..10).collect()),
        Request::new(1, (1..6).collect()),
    ];
    let expect = serial_reference(requests.clone());
    let got = run_once(LaunchConfig::preset("tiny").with_drce(true), requests.clone());
    // compare logits on valid positions only (pad rows are zeroed packed)
    let v = 128;
    for (b, r) in requests.iter().enumerate() {
        for s in 0..r.tokens.len() {
            let a = &expect.data[(b * 16 + s) * v..(b * 16 + s + 1) * v];
            let g = &got.data[(b * 16 + s) * v..(b * 16 + s + 1) * v];
            let diff = a
                .iter()
                .zip(g)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 2e-2, "drce row ({b},{s}) diff {diff}");
        }
    }
}

#[test]
fn drce_with_tp2_matches_serial() {
    let requests = vec![
        Request::new(0, (1..9).collect()),
        Request::new(1, (1..7).collect()),
    ];
    let expect = serial_reference(requests.clone());
    let got = run_once(
        LaunchConfig::preset("tiny").with_parallel(2, 1).with_drce(true),
        requests.clone(),
    );
    let v = 128;
    for (b, r) in requests.iter().enumerate() {
        for s in 0..r.tokens.len() {
            let a = &expect.data[(b * 16 + s) * v..(b * 16 + s + 1) * v];
            let g = &got.data[(b * 16 + s) * v..(b * 16 + s + 1) * v];
            let diff = a.iter().zip(g).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(diff < 2e-2, "drce+tp row ({b},{s}) diff {diff}");
        }
    }
}

#[test]
fn blocking_comms_still_correct() {
    // FT-style rendezvous pipeline: slower, but must compute the same
    let expect = serial_reference(reqs(2, 10));
    let got = run_once(
        LaunchConfig::preset("tiny").with_parallel(1, 2).with_blocking_comms(true),
        reqs(2, 10),
    );
    assert!(got.max_abs_diff(&expect) < 2e-2);
}

#[test]
fn many_batches_in_flight_keep_order() {
    // NBPP: multiple batches flow the pipeline concurrently; results must
    // pair with their requests (consistency queue)
    let engine = Engine::launch(LaunchConfig::preset("tiny").with_parallel(1, 2)).unwrap();
    let mut rrefs = Vec::new();
    for k in 0..8u64 {
        // batch signature: all tokens equal k+1 -> deterministic per batch
        let r = vec![Request::new(k, vec![(k + 1) as i32; 8])];
        rrefs.push((k, engine.infer_batch(r).unwrap()));
    }
    let mut outs = Vec::new();
    for (k, r) in rrefs {
        let out = r.to_here().unwrap();
        outs.push((k, out));
    }
    // identical inputs k produce identical logits every time they repeat
    let engine2_expected: Vec<Tensor> = outs.iter().map(|(_, o)| o.logits.clone()).collect();
    for (k, out) in &outs {
        // re-run the same batch serially and compare
        let r = vec![Request::new(*k, vec![(*k + 1) as i32; 8])];
        let rref = engine.infer_batch(r).unwrap();
        let again = rref.to_here().unwrap();
        let diff = again.logits.max_abs_diff(&out.logits);
        assert!(diff < 1e-4, "batch {k} not reproducible, diff {diff}");
    }
    drop(engine2_expected);
    let m = engine.metrics_snapshot();
    assert!(m.batches() >= 16);
    engine.shutdown();
}

#[test]
fn batcher_submit_path_works() {
    let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let futures: Vec<_> = (0..4)
        .map(|i| engine.submit(vec![(i % 50) as i32 + 1; 6]).unwrap())
        .collect();
    for f in &futures {
        let tok = f.to_here().unwrap();
        assert!((0..128).contains(&tok), "token {tok} out of vocab");
    }
    engine.shutdown();
}

#[test]
fn pmep_engine_matches_resident() {
    let expect = serial_reference(reqs(2, 10));
    let got = run_once(
        LaunchConfig::preset("tiny").with_memory(MemoryMode::Pmep {
            n_local: 2,
            pool: PoolConfig::pmep(),
        }),
        reqs(2, 10),
    );
    assert!(got.max_abs_diff(&expect) < 1e-4, "pmep changed the numbers");
}

#[test]
fn bminf_engine_matches_resident() {
    let expect = serial_reference(reqs(2, 10));
    let got = run_once(
        LaunchConfig::preset("tiny").with_memory(MemoryMode::Bminf { n_local: 2 }),
        reqs(2, 10),
    );
    assert!(got.max_abs_diff(&expect) < 1e-4, "bminf changed the numbers");
}

#[test]
fn oversize_batch_is_rejected() {
    let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    // tiny buckets max at (4,32): 5 requests can't fit
    assert!(engine.infer_batch(reqs(5, 8)).is_err());
    // and a request longer than any bucket
    assert!(engine.infer_batch(reqs(1, 64)).is_err());
    engine.shutdown();
}
