//! Fault-tolerant replica fleet, differentially: kill one of three
//! replicas mid-decode and every client stream — including the victims
//! that failed over — must be byte-identical to a no-kill control run;
//! drain a replica and its teardown must prove zero K/V blocks in use on
//! either tier; run the full saturation scenario through a fleet with a
//! seeded kill schedule and lose nothing.
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::engine::{Engine, GenRef, GenRequest, LaunchConfig};
use energonai::coordinator::fleet::{Fleet, ReplicaState};
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use energonai::workload::loadgen::{
    parity_mismatches, run_fleet_saturation, run_saturation, Outcome, SaturationScenario,
};
use std::sync::Mutex;
use std::time::Duration;

/// Serializes every test in this binary: all of them assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_ready() -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", 1).is_empty() && man.has_kv_prefill("tiny", 1);
    if !ok {
        eprintln!("skipping: decode artifacts missing for tiny/tp1");
    }
    ok
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// Longest compiled prefill bucket for the tiny preset — the context cap
/// the load generator must respect.
fn max_context(engine: &Engine) -> usize {
    engine.manifest.shape_points("tiny").iter().map(|&(_, s)| s).max().unwrap()
}

/// The acceptance bar: kill 1 of 3 replicas while its sessions are
/// mid-decode. Victim sessions must fail over and complete with streams
/// byte-identical to a single-engine control (zero committed tokens
/// lost, no mid-stream error surfaces), survivors stay untouched, and
/// the whole fleet tears down without leaking a block on either tier.
#[test]
fn kill_one_of_three_mid_decode_keeps_streams_byte_identical() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let all = prompts(9);

    // control: one plain engine, no fleet, no faults
    let control = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let expect: Vec<Vec<i32>> =
        all.iter().map(|p| control.generate(p.clone(), 8).unwrap()).collect();
    control.shutdown();

    let before = kvcache::global_stats();
    // replica 0 is the designated victim: a replica-scoped delay on every
    // batch keeps its sessions mid-decode long enough for the kill to
    // land while they still owe tokens
    let base = LaunchConfig::preset("tiny").with_faults("delay5ms@every1+0@r0", 2209);
    let fleet = Fleet::launch(base, 3).unwrap();
    // headroom placement round-robins an idle fleet, so replica 0 is
    // guaranteed a share of the nine sessions
    let grefs: Vec<GenRef> = all
        .iter()
        .map(|p| fleet.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    // let the fast replicas stream while the victim crawls, then kill it
    std::thread::sleep(Duration::from_millis(10));
    fleet.kill(0).unwrap();
    assert_eq!(fleet.replica_state(0), Some(ReplicaState::Dead));

    let got: Vec<Vec<i32>> = grefs
        .iter()
        .map(|g| g.to_here().expect("no client may see a mid-stream error"))
        .collect();
    assert_eq!(got, expect, "a failed-over stream diverged from the control");

    let stats = fleet.stats();
    assert_eq!(stats.kills, 1);
    assert!(
        stats.failovers >= 1,
        "the 5ms/step victim cannot have finished all its sessions in 10ms"
    );
    assert_eq!(stats.failover_us.len() as u64, stats.failovers);
    assert_eq!(stats.healthy(), 2);

    fleet.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "failover leaked device blocks");
    assert_eq!(after.host_bytes, before.host_bytes, "failover leaked host bytes");
    assert_eq!(after.double_free, before.double_free, "a session was released twice");
}

/// Drain: no new placements, existing sessions run to completion, and
/// the teardown proves zero K/V blocks in use on both tiers. The
/// survivor keeps serving afterwards.
#[test]
fn drain_finishes_sessions_and_tears_down_with_zero_blocks() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let all = prompts(4);

    let control = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let expect: Vec<Vec<i32>> =
        all.iter().map(|p| control.generate(p.clone(), 6).unwrap()).collect();
    let late_expect = control.generate(all[0].clone(), 4).unwrap();
    control.shutdown();

    let before = kvcache::global_stats();
    let fleet = Fleet::launch(LaunchConfig::preset("tiny"), 2).unwrap();
    // idle-fleet headroom placement alternates replicas, so replica 0
    // holds sessions when the drain begins
    let grefs: Vec<GenRef> = all
        .iter()
        .map(|p| fleet.generate_stream(GenRequest::new(p.clone(), 6)).unwrap())
        .collect();
    let report = fleet.drain(0).unwrap();
    assert_eq!(report.replica, 0);
    assert_eq!(report.device_blocks, 0, "drained replica still held device blocks");
    assert_eq!(report.host_blocks, 0, "drained replica still held host blocks");
    assert_eq!(fleet.replica_state(0), Some(ReplicaState::Dead));
    // a second drain of the same replica is a caller error
    assert!(fleet.drain(0).is_err());

    // every session that was in flight completed with the control bytes
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "a drain changed what a stream said");

    // the survivor still serves — and identically
    assert_eq!(fleet.generate(all[0].clone(), 4).unwrap(), late_expect);
    assert_eq!(fleet.stats().drains, 1);

    fleet.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "drain leaked device blocks");
    assert_eq!(after.host_bytes, before.host_bytes, "drain leaked host bytes");
    assert_eq!(after.double_free, before.double_free, "a session was released twice");
}

/// The saturation scenario through a 3-replica fleet with a seeded kill
/// schedule: no turn may error, survivor parity against a single-engine
/// no-kill control must hold, and nothing may leak fleet-wide.
#[test]
fn fleet_saturation_with_a_kill_schedule_loses_nothing() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let scenario = SaturationScenario::new(2209, 8, 3);

    let control_engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
    let cap = max_context(&control_engine);
    let control = run_saturation(&control_engine, &scenario, cap);
    control_engine.shutdown();
    assert_eq!(control.errors, 0, "control must be clean: {:?}", control.streams);
    assert_eq!(control.completed, control.turns());

    let before = kvcache::global_stats();
    let fleet = Fleet::launch(LaunchConfig::preset("tiny"), 3).unwrap();
    let kills = scenario.kill_schedule(3, 1, Duration::from_millis(60));
    assert_eq!(kills.len(), 1);
    let report = run_fleet_saturation(&fleet, &scenario, cap, &kills);

    // the kill fired: exactly one replica is dead, two still serve
    assert_eq!(fleet.replica_state(kills[0].replica), Some(ReplicaState::Dead));
    assert_eq!(fleet.stats().healthy(), 2);
    // no caps, no chaos, transparent failover: every turn completes
    assert_eq!(
        report.errors,
        0,
        "a kill surfaced as a client error: {:?}",
        report
            .streams
            .iter()
            .filter(|s| matches!(s.outcome, Outcome::Error(_)))
            .collect::<Vec<_>>()
    );
    assert_eq!(report.shed, 0);
    assert_eq!(report.completed, report.turns(), "a kill lost a session");
    let diffs = parity_mismatches(&control, &report);
    assert!(diffs.is_empty(), "survivor streams diverged:\n{}", diffs.join("\n"));

    fleet.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "fleet saturation leaked blocks");
    assert_eq!(after.host_bytes, before.host_bytes, "fleet saturation leaked host bytes");
    assert_eq!(after.double_free, before.double_free, "a session was released twice");
}

/// API contract around the failure verbs.
#[test]
fn failure_verbs_reject_nonsense() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let fleet = Fleet::launch(LaunchConfig::preset("tiny"), 2).unwrap();
    assert!(fleet.kill(7).is_err(), "out-of-range replica");
    assert!(fleet.drain(7).is_err());
    fleet.kill(1).unwrap();
    assert!(fleet.kill(1).is_err(), "double kill");
    assert!(fleet.drain(1).is_err(), "draining the dead");
    // the survivor still serves
    assert!(fleet.generate(vec![1, 2, 3], 2).is_ok());
    fleet.shutdown();
}
