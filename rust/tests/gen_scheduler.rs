//! Iteration-level generation scheduler: the continuation-batched request
//! lifecycle end to end. Concurrent multi-token generations must coalesce
//! into shared decode buckets without changing any greedy token, streams
//! must arrive in order, and stop tokens must cut sessions short.

use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
use energonai::workload::GenScenario;

fn engine() -> Engine {
    Engine::launch(LaunchConfig::preset("tiny")).unwrap()
}

/// Concurrent sessions interleave in shared buckets yet produce exactly
/// the tokens sequential generation produces (greedy decoding is
/// deterministic and batch-composition independent).
#[test]
fn concurrent_generations_match_sequential() {
    let engine = engine();
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| vec![(i * 17 + 5) as i32 % 100 + 1, (i + 2) as i32, 9])
        .collect();

    // sequential oracle: one session at a time
    let expect: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| engine.generate(p.clone(), 8).unwrap())
        .collect();

    // all four at once, submitted back-to-back (generate_stream is
    // non-blocking, so the sessions are live simultaneously)
    let grefs: Vec<_> = prompts
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "concurrent sessions changed greedy tokens");
    engine.shutdown();
}

/// The acceptance bar: ≥4 concurrent 16-token generations batch their
/// decode steps together — mean batch occupancy strictly above 1.
#[test]
fn concurrent_decode_steps_share_batches() {
    let engine = engine();
    let sc = GenScenario::concurrent(8, 16, 8, engine.cfg.vocab);
    let grefs: Vec<_> = sc
        .prompts()
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, sc.new_tokens)).unwrap())
        .collect();
    let mut total_generated = 0;
    for g in &grefs {
        total_generated += g.to_here().unwrap().len() - g.prompt().len();
    }
    assert!(total_generated >= 8, "sessions barely generated: {total_generated}");

    let m = engine.metrics_snapshot();
    assert_eq!(m.tokens(), total_generated as u64, "{}", m.summary());
    assert!(
        m.mean_occupancy() > 1.0,
        "decode steps never coalesced: {}",
        m.summary()
    );
    // the generation axes must be populated
    assert!(m.ttft_percentile(0.5).is_some(), "{}", m.summary());
    assert!(m.token_percentile(0.5).is_some(), "{}", m.summary());
    assert!(m.tokens_per_sec() > 0.0, "{}", m.summary());
    engine.shutdown();
}

/// A stop token ends the session early, and the stop token itself is the
/// last emitted token.
#[test]
fn stop_token_exits_early() {
    let engine = engine();
    let prompt = vec![5, 9, 2];
    let free = engine.generate(prompt.clone(), 6).unwrap();
    assert!(free.len() > prompt.len() + 1, "need ≥2 generated tokens to test stop");
    // stop at the second generated token
    let stop = free[prompt.len() + 1];
    let got = engine
        .generate_stream(GenRequest::new(prompt.clone(), 6).with_stop(stop))
        .unwrap()
        .to_here()
        .unwrap();
    // expected: the free-running sequence truncated right after the first
    // occurrence of `stop` among generated tokens
    let cut = free[prompt.len()..].iter().position(|&t| t == stop).unwrap();
    let expect = &free[..prompt.len() + cut + 1];
    assert_eq!(got, expect, "stop token did not truncate the session");
    assert_eq!(*got.last().unwrap(), stop);
    engine.shutdown();
}

/// `GenRef::next` streams tokens incrementally, in emission order, and
/// agrees with the final `to_here` sequence.
#[test]
fn streaming_matches_final_sequence() {
    let engine = engine();
    let prompt = vec![3, 1, 4, 1, 5];
    let gref = engine
        .generate_stream(GenRequest::new(prompt.clone(), 6))
        .unwrap();
    let mut streamed = Vec::new();
    while let Some(t) = gref.next().unwrap() {
        streamed.push(t);
        assert!(gref.n_generated() >= streamed.len());
    }
    assert!(!streamed.is_empty());
    assert!(streamed.len() <= 6);
    let full = gref.to_here().unwrap();
    assert_eq!(full[..prompt.len()], prompt[..]);
    assert_eq!(full[prompt.len()..], streamed[..]);
    // and the blocking wrapper produces the same continuation
    assert_eq!(engine.generate(prompt, 6).unwrap(), full);
    engine.shutdown();
}

/// Every concurrent `generate` call gets its own request id — none of the
/// sessions can collide (the seed's `generate` used id 0 for every step).
#[test]
fn concurrent_generate_calls_do_not_collide() {
    let engine = std::sync::Arc::new(engine());
    let prompts: Vec<Vec<i32>> = (0..6).map(|i| vec![(i + 1) as i32; 4]).collect();
    let expect: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| engine.generate(p.clone(), 5).unwrap())
        .collect();
    let handles: Vec<_> = prompts
        .iter()
        .cloned()
        .map(|p| {
            let engine = engine.clone();
            std::thread::spawn(move || engine.generate(p, 5).unwrap())
        })
        .collect();
    for (h, e) in handles.into_iter().zip(&expect) {
        assert_eq!(&h.join().unwrap(), e, "a racing generate call was corrupted");
    }
    match std::sync::Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => panic!("engine still referenced"),
    }
}

/// Sessions queued but unfinished at shutdown are drained, not dropped.
#[test]
fn shutdown_drains_live_sessions() {
    let engine = engine();
    let grefs: Vec<_> = (0..5)
        .map(|i| {
            engine
                .generate_stream(GenRequest::new(vec![i as i32 + 1, 7], 4))
                .unwrap()
        })
        .collect();
    engine.shutdown();
    for g in grefs {
        let out = g.to_here().expect("session must complete before teardown");
        assert!(out.len() > 2, "no tokens generated: {out:?}");
    }
}

/// Regression (watchdog vs long generations): a generation whose total
/// wall time exceeds `batch_deadline_ms` must still complete when every
/// individual engine step is healthy. The seed watchdog compared every
/// pending batch's publish-time age against the deadline, so a
/// continuation batch re-enqueued behind a dispatch backlog aged from
/// the moment it was published — not from when the workers actually got
/// to it — and a long generation under a short deadline could be
/// poisoned spuriously. The fixed watchdog only ages the head batch
/// (minimum ticket) from its promotion, so a healthy backlog can never
/// expire.
#[test]
fn short_deadline_does_not_poison_long_generations() {
    let mut lc = LaunchConfig::preset("tiny");
    // short relative to a whole multi-session run, generous relative to
    // one engine step — exactly the regime where only queueing time
    // could (wrongly) trip the watchdog
    lc.engine.batch_deadline_ms = 250;
    lc.engine.pool_threads = 4; // several batches in flight -> a backlog
    let engine = Engine::launch(lc).unwrap();
    // enough concurrent long generations that total wall time clears the
    // deadline comfortably
    let grefs: Vec<_> = (0..8)
        .map(|i| {
            engine
                .generate_stream(GenRequest::new(vec![(i % 90 + 1) as i32, 7, 3], 16))
                .unwrap()
        })
        .collect();
    let mut total = 0;
    for g in &grefs {
        let out = g.to_here().expect("healthy generation was poisoned by the watchdog");
        total += out.len() - 3;
    }
    assert!(total >= 8, "sessions barely generated: {total}");
    engine.shutdown();
}

/// max_new_tokens == 0 is rejected; empty prompts are rejected.
#[test]
fn invalid_gen_requests_rejected() {
    let engine = engine();
    assert!(engine.generate_stream(GenRequest::new(vec![1, 2], 0)).is_err());
    assert!(engine.generate_stream(GenRequest::new(vec![], 4)).is_err());
    // oversized prompt propagates the batcher error and leaks no session
    assert!(engine.generate_stream(GenRequest::new(vec![1; 500], 4)).is_err());
    assert_eq!(engine.session_count(), 0);
    engine.shutdown();
}
