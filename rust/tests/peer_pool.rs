//! Three-tier KV cache (device → peer → host), differentially: parking
//! cold sessions in a ring peer's spare device memory, fetching them
//! back on re-entry, and demoting the coldest parked images to host
//! under peer pressure must all be invisible in the token streams
//! (greedy decoding is deterministic, so any divergence is a tiering
//! bug) — with or without the overlapped copier thread — while a device
//! slab sized for K sessions serves many more than K.
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::engine::{Engine, GenRef, GenRequest, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use std::sync::Mutex;

/// Serializes every test in this binary: several assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Decode artifacts for (tiny, tp) present? When not, the test is a
/// no-op — matching the seed state instead of adding failures.
fn artifacts_ready(tp: usize) -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", tp).is_empty() && man.has_kv_prefill("tiny", tp);
    if !ok {
        eprintln!("skipping: decode artifacts missing for tiny/tp{tp}");
    }
    ok
}

/// A three-tier engine: `device_blocks` per worker, `peer_blocks` of
/// ring-peer budget, unlimited host behind both. Two dispatcher threads
/// bound the number of pinned (in-flight) sessions.
fn launch_peered(tp: usize, device_blocks: usize, peer_blocks: usize, copier: bool) -> Engine {
    let mut lc = LaunchConfig::preset("tiny")
        .with_parallel(tp, 1)
        .with_kv_spill(device_blocks, 0)
        .with_kv_peer(peer_blocks)
        .with_kv_copier(copier);
    lc.engine.pool_threads = 2;
    Engine::launch(lc).unwrap()
}

fn launch_resident(tp: usize) -> Engine {
    Engine::launch(LaunchConfig::preset("tiny").with_parallel(tp, 1)).unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// No blocks or bytes may remain on any tier after a drain, and the
/// loud-path counters must not have moved.
fn assert_all_tiers_drained(before: &kvcache::KvStats, what: &str) {
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "{what}: device blocks leaked");
    assert_eq!(after.host_bytes, before.host_bytes, "{what}: host-tier bytes leaked");
    assert_eq!(after.peer_bytes, before.peer_bytes, "{what}: peer-tier bytes leaked");
    assert_eq!(after.sessions_parked, before.sessions_parked, "{what}: parked sessions leaked");
    assert_eq!(after.sessions_spilled, before.sessions_spilled, "{what}: spilled sessions leaked");
    assert_eq!(after.double_free, before.double_free, "{what}: double free");
    assert_eq!(
        after.gather_spilled, before.gather_spilled,
        "{what}: a decode bucket dispatched against an off-device session"
    );
}

/// The tentpole acceptance bar: with a device tier sized for ~K sessions
/// and a peer tier behind it, 3K+ concurrent sessions all complete, park
/// and fetch counters move, and every token stream is byte-identical to
/// the resident-only run.
fn assert_peer_parity(tp: usize, n_sessions: usize, device_blocks: usize, copier: bool) {
    if !artifacts_ready(tp) {
        return;
    }
    let _guard = stats_guard();

    let resident = launch_resident(tp);
    assert!(resident.kv_cache_on(), "decode artifacts present but cache off");
    assert!(!resident.kv_peer_on());
    let expect: Vec<Vec<i32>> = prompts(n_sessions)
        .into_iter()
        .map(|p| resident.generate(p, 8).unwrap())
        .collect();
    resident.shutdown();

    let before = kvcache::global_stats();
    // a peer budget as large as the device tier: every relieve() victim
    // parks instead of spilling until the ring peer fills up
    let peered = launch_peered(tp, device_blocks, device_blocks, copier);
    assert!(peered.kv_peer_on());
    let grefs: Vec<GenRef> = prompts(n_sessions)
        .into_iter()
        .map(|p| peered.generate_stream(GenRequest::new(p, 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "three-tier decode diverged (tp={tp} copier={copier})");

    let stats = peered.metrics_snapshot().kvcache_stats();
    assert!(
        stats.parks > before.parks,
        "peer tier of {device_blocks} blocks never parked under {n_sessions} sessions"
    );
    assert!(stats.fetches > before.fetches, "parked sessions never fetched back");
    peered.shutdown();
    assert_all_tiers_drained(&before, "peer parity");
}

#[test]
fn peered_decode_matches_resident_tp1() {
    // tiny prompts run 2..8 tokens -> 9..16 positions -> 1..2 blocks per
    // session. 8 device blocks ≈ 4 sessions; 16 concurrent = 4x that.
    assert_peer_parity(1, 16, 8, false);
}

#[test]
fn peered_decode_matches_resident_tp2() {
    assert_peer_parity(2, 16, 8, false);
}

/// Same bar with the overlapped copier: staged landings must settle
/// before every forward, so the streams stay byte-identical.
#[test]
fn copier_overlap_preserves_parity_tp1() {
    assert_peer_parity(1, 16, 8, true);
}

#[test]
fn copier_overlap_preserves_parity_tp2() {
    assert_peer_parity(2, 16, 8, true);
}

/// A deliberately tiny peer budget behind a tiny device tier: the
/// workload overflows device *and* peer, so the coldest parked images
/// demote peer → host — and the streams still match the resident run.
#[test]
fn peer_pressure_demotes_to_host_with_parity() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();

    let resident = launch_resident(1);
    let expect: Vec<Vec<i32>> = prompts(16)
        .into_iter()
        .map(|p| resident.generate(p, 8).unwrap())
        .collect();
    resident.shutdown();

    let before = kvcache::global_stats();
    let peered = launch_peered(1, 6, 2, false);
    let grefs: Vec<GenRef> = prompts(16)
        .into_iter()
        .map(|p| peered.generate_stream(GenRequest::new(p, 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "decode diverged under peer pressure");

    let stats = peered.metrics_snapshot().kvcache_stats();
    assert!(stats.parks > before.parks, "2-block peer tier never parked");
    assert!(
        stats.demotes > before.demotes || stats.spills > before.spills,
        "overflow past device+peer never reached the host tier"
    );
    peered.shutdown();
    assert_all_tiers_drained(&before, "peer pressure");
}

/// Cancelling sessions mid-generation while parks and fetches are in
/// flight: survivors stay byte-identical and every tier fully drains —
/// the guard ring covers blocks freed off the peer tier too.
#[test]
fn cancel_mid_park_leaks_nothing_on_any_tier() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let all = prompts(16);

    let control = launch_resident(1);
    let expect: Vec<Vec<i32>> = all
        .iter()
        .step_by(2)
        .map(|p| control.generate(p.clone(), 8).unwrap())
        .collect();
    control.shutdown();

    let before = kvcache::global_stats();
    let engine = launch_peered(1, 8, 8, false);
    let grefs: Vec<GenRef> = all
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    // hang up every odd-indexed client (its session may be queued, in
    // flight, parked in the peer, or demoted — all paths must reclaim)
    for g in grefs.iter().skip(1).step_by(2) {
        g.cancel();
    }
    let survivors: Vec<Vec<i32>> =
        grefs.iter().step_by(2).map(|g| g.to_here().unwrap()).collect();
    assert_eq!(survivors, expect, "a cancelled neighbour changed a survivor's stream");
    engine.shutdown();
    assert_all_tiers_drained(&before, "cancel mid-park");
}

/// Chaos delays at the worker reply boundary interleave parks, fetches,
/// and demotes differently on every run — the streams must not care.
#[test]
fn chaos_delays_never_perturb_peered_streams() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let ps = prompts(12);

    let clean = launch_peered(1, 8, 8, true);
    let expect: Vec<Vec<i32>> =
        ps.iter().map(|p| clean.generate(p.clone(), 6).unwrap()).collect();
    clean.shutdown();

    let before = kvcache::global_stats();
    let mut lc = LaunchConfig::preset("tiny")
        .with_kv_spill(8, 0)
        .with_kv_peer(8)
        .with_kv_copier(true)
        .with_faults("delay2ms@every3+1", 7);
    lc.engine.pool_threads = 2;
    let engine = Engine::launch(lc).unwrap();
    let got: Vec<Vec<i32>> =
        ps.iter().map(|p| engine.generate(p.clone(), 6).unwrap()).collect();
    assert_eq!(got, expect, "a delay fault changed a stream under the peer tier");
    engine.shutdown();
    assert_all_tiers_drained(&before, "chaos delays");
}

/// Sequential waves through the three-tier hierarchy: the device slab
/// must not grow beyond its cap, and device, peer, and host must all
/// fully drain between waves' final settle.
#[test]
fn waves_respect_the_device_cap_with_peer_tier() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let before = kvcache::global_stats();
    let engine = launch_peered(1, 8, 4, true);
    for _ in 0..3 {
        let grefs: Vec<GenRef> = prompts(12)
            .into_iter()
            .map(|p| engine.generate_stream(GenRequest::new(p, 4)).unwrap())
            .collect();
        for g in &grefs {
            g.to_here().unwrap();
        }
    }
    let stats = engine.metrics_snapshot().kvcache_stats();
    assert_eq!(
        stats.overflow_blocks, before.overflow_blocks,
        "admission control let the device tier overflow"
    );
    engine.shutdown();
    assert_all_tiers_drained(&before, "waves");
}
