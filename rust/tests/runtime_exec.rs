//! Integration: the AOT → PJRT path end to end against real artifacts.
//!
//! Requires `make artifacts` (the tiny preset). These tests prove the HLO
//! text emitted by python lowers, compiles on the Rust PJRT CPU client,
//! and computes the same numbers as the JAX reference — the core
//! correctness contract of the three-layer architecture.

use energonai::config::ModelConfig;
use energonai::model::{shard_layer, ModelWeights};
use energonai::runtime::{find_artifacts, valid_len_arg, Device, Manifest};
use energonai::tensor::{drce, IntTensor, Tensor, Value};
use energonai::util::rng::Rng;

fn setup() -> (Manifest, Device, ModelConfig, ModelWeights) {
    let manifest = Manifest::load(find_artifacts().unwrap()).unwrap();
    let device = Device::new(0).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let weights = ModelWeights::random(&cfg, 42);
    (manifest, device, cfg, weights)
}

fn randx(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::randn(shape, 0.5, &mut rng)
}

#[test]
fn layer_full_executes_and_is_deterministic() {
    let (man, dev, cfg, w) = setup();
    let v = man.get("tiny_layer_full_b2_s16").unwrap();
    let x = randx(&[2, 16, cfg.hidden], 1);
    let mut args = vec![Value::F32(x.clone()), valid_len_arg(&[16, 16])];
    args.extend(w.layers[0].all_args());
    let out1 = dev.execute(&man, v, &args).unwrap();
    let out2 = dev.execute(&man, v, &args).unwrap();
    assert_eq!(out1[0].shape, vec![2, 16, cfg.hidden]);
    assert_eq!(out1[0], out2[0]);
    // output must differ from input (the layer does something)
    assert!(out1[0].max_abs_diff(&x) > 1e-3);
    // compile happened once, execute twice
    let stats = *dev.stats.borrow();
    assert_eq!(stats.compiles, 1);
    assert_eq!(stats.executions, 2);
}

#[test]
fn tp_shards_reassemble_to_full_layer() {
    let (man, dev, cfg, w) = setup();
    let full = man.get("tiny_layer_full_b2_s16").unwrap();
    let attn = man.get("tiny_attn_shard_tp2_b2_s16").unwrap();
    let mlp = man.get("tiny_mlp_shard_tp2_r32").unwrap();

    let x = randx(&[2, 16, cfg.hidden], 2);
    let valid = valid_len_arg(&[16, 9]);
    let lw = &w.layers[0];

    // reference: full layer in one executable
    let mut args = vec![Value::F32(x.clone()), valid.clone()];
    args.extend(lw.all_args());
    let expect = dev.execute(&man, full, &args).unwrap().remove(0);

    // sharded: attn partials -> sum -> r = x + sum -> mlp partials -> sum
    let shards: Vec<_> = (0..2).map(|r| shard_layer(&cfg, lw, 2, r)).collect();
    let partials: Vec<Tensor> = shards
        .iter()
        .map(|s| {
            let mut a = vec![Value::F32(x.clone()), valid.clone()];
            a.extend(s.attn_args());
            dev.execute(&man, attn, &a).unwrap().remove(0)
        })
        .collect();
    let attn_sum = Tensor::sum_of(&partials);
    let r = x.add(&attn_sum);
    let r2 = r.clone().reshape(&[32, cfg.hidden]);
    let mlp_partials: Vec<Tensor> = shards
        .iter()
        .map(|s| {
            let mut a = vec![Value::F32(r2.clone())];
            a.extend(s.mlp_args());
            dev.execute(&man, mlp, &a).unwrap().remove(0)
        })
        .collect();
    let y = r.add(&Tensor::sum_of(&mlp_partials).reshape(&[2, 16, cfg.hidden]));

    let diff = y.max_abs_diff(&expect);
    assert!(diff < 2e-3, "tp reassembly diff {diff}");
}

#[test]
fn drce_packed_path_matches_padded_on_valid_rows() {
    let (man, dev, cfg, w) = setup();
    let full = man.get("tiny_layer_full_b2_s16").unwrap();
    let drce_v = man.get("tiny_drce_attn_shard_tp1_b2_s16_t16").unwrap();
    let mlp = man.get("tiny_mlp_shard_tp1_r16").unwrap();

    let lens = [9usize, 7];
    let maps = drce::make_maps(&lens, 16, 16).unwrap();
    let mut x = randx(&[2, 16, cfg.hidden], 3);
    // zero pad rows like the batcher does
    {
        let flat = x.clone().reshape(&[32, cfg.hidden]);
        let mut z = flat;
        for (b, &vl) in lens.iter().enumerate() {
            for s in vl..16 {
                z.row_mut(b * 16 + s).fill(0.0);
            }
        }
        x = z.reshape(&[2, 16, cfg.hidden]);
    }
    let valid = valid_len_arg(&lens);
    let lw = &w.layers[0];

    // padded reference
    let mut args = vec![Value::F32(x.clone()), valid.clone()];
    args.extend(lw.all_args());
    let expect = dev.execute(&man, full, &args).unwrap().remove(0).reshape(&[32, cfg.hidden]);

    // packed path
    let x_flat = x.clone().reshape(&[32, cfg.hidden]);
    let x_packed = drce::pack(&x_flat, &maps);
    let mut a = vec![
        Value::F32(x_packed.clone()),
        valid.clone(),
        Value::I32(maps.unpad_map.clone()),
        Value::I32(maps.pad_map.clone()),
    ];
    a.extend(lw.attn_args());
    let attn_partial = dev.execute(&man, drce_v, &a).unwrap().remove(0);
    let r_packed = x_packed.add(&attn_partial);
    let mut a = vec![Value::F32(r_packed.clone())];
    a.extend(lw.mlp_args());
    let mlp_partial = dev.execute(&man, mlp, &a).unwrap().remove(0);
    let y_packed = r_packed.add(&mlp_partial);

    for j in 0..maps.n_valid {
        let src = maps.unpad_map.data[j] as usize;
        let diff: f32 = y_packed
            .row(j)
            .iter()
            .zip(expect.row(src))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 2e-3, "row {j} diff {diff}");
    }
}

#[test]
fn embed_then_logits_pipeline() {
    let (man, dev, cfg, w) = setup();
    let embed = man.get("tiny_embed_b2_s16").unwrap();
    let logits = man.get("tiny_logits_b2_s16").unwrap();

    let ids = IntTensor::new(&[2, 16], (0..32).map(|i| (i % cfg.vocab as i32)).collect());
    let mut args = vec![Value::I32(ids)];
    args.extend(w.embed_args());
    let x = dev.execute(&man, embed, &args).unwrap().remove(0);
    assert_eq!(x.shape, vec![2, 16, cfg.hidden]);

    let mut args = vec![Value::F32(x)];
    args.extend(w.logits_args());
    let z = dev.execute(&man, logits, &args).unwrap().remove(0);
    assert_eq!(z.shape, vec![2, 16, cfg.vocab]);
    assert!(z.data.iter().all(|v| v.is_finite()));
}

#[test]
fn wrong_args_are_rejected_not_executed() {
    let (man, dev, cfg, _w) = setup();
    let v = man.get("tiny_layer_full_b2_s16").unwrap();
    let args = vec![Value::F32(Tensor::zeros(&[2, 16, cfg.hidden]))];
    assert!(dev.execute(&man, v, &args).is_err());
}
