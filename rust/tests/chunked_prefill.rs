//! Chunked prefill co-scheduled with decode, differentially.
//!
//! Splitting a long prompt's prefill into fixed-size chunk waves that
//! seed the paged K/V cache incrementally must be invisible in every
//! token stream: the final chunk's argmax at the prompt boundary is the
//! same first token the monolithic prefill computes, and everything
//! after it is plain incremental decode. The suite pins that byte-parity
//! at tp=1 and tp=2, with the prefix cache on and off, with the spill
//! tier on, and across the failure paths (cancel mid-chunk, watchdog
//! poisoning mid-chunk) — with zero block leaks on both tiers.
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::engine::{Engine, GenRef, GenRequest, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use std::sync::Mutex;

/// Serializes every test in this binary: several assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Chunk windows reuse the verify kernel family, so chunked prefill
/// needs the decode + kv-prefill + verify artifacts for (tiny, tp).
fn artifacts_ready(tp: usize) -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", tp).is_empty()
        && man.has_kv_prefill("tiny", tp)
        && !man.verify_points("tiny", tp).is_empty();
    if !ok {
        eprintln!("skipping: decode/verify artifacts missing for tiny/tp{tp}");
    }
    ok
}

/// Chunk window 4 over the tiny preset's compiled verify ks {2, 4}.
const CHUNK: usize = 4;

fn launch_chunked(tp: usize) -> Engine {
    Engine::launch(
        LaunchConfig::preset("tiny").with_parallel(tp, 1).with_prefill_chunk(CHUNK, 1),
    )
    .unwrap()
}

fn launch_monolithic(tp: usize) -> Engine {
    Engine::launch(LaunchConfig::preset("tiny").with_parallel(tp, 1)).unwrap()
}

/// Mixed traffic: prompts long enough that chunking engages (several
/// chunk waves each, some with a stepping-decode tail) interleaved with
/// short prompts that stay monolithic even with the knob on.
fn mixed_prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = if i % 3 == 0 { 2 + (i * 3) % 5 } else { 10 + (i * 7) % 17 };
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// The acceptance bar: with chunking on, mixed long/short traffic emits
/// byte-identical token streams to the monolithic engine — sequentially
/// and concurrently — while actually taking the chunked path.
fn assert_parity(tp: usize) {
    if !artifacts_ready(tp) {
        return;
    }
    let _guard = stats_guard();
    let ps = mixed_prompts(8);
    let mono = launch_monolithic(tp);
    assert!(!mono.chunked_prefill_on(), "prefill_chunk defaults to 0 = off");
    let expect: Vec<Vec<i32>> =
        ps.iter().map(|p| mono.generate(p.clone(), 8).unwrap()).collect();
    mono.shutdown();

    let before = kvcache::global_stats();
    let on = launch_chunked(tp);
    assert!(on.chunked_prefill_on(), "verify artifacts live but chunking not on");
    assert_eq!(on.chunk_ks(), vec![2, 4], "tiny compiles verify ks 2 and 4");
    // sequential: each long prompt runs its chunk waves alone
    let got: Vec<Vec<i32>> = ps.iter().map(|p| on.generate(p.clone(), 8).unwrap()).collect();
    assert_eq!(got, expect, "chunked prefill diverged (sequential, tp={tp})");
    // concurrent: chunk waves, stepping tails, short monolithic prefills
    // and decode buckets coalesce through one queue
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| on.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "chunked prefill diverged (concurrent, tp={tp})");
    on.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(
        after.blocks_in_use, before.blocks_in_use,
        "chunked prefill leaked blocks across shutdown (tp={tp})"
    );
    assert_eq!(after.double_free, before.double_free, "a chunked session was freed twice");
}

#[test]
fn chunked_matches_monolithic_byte_identically_tp1() {
    assert_parity(1);
}

#[test]
fn chunked_matches_monolithic_byte_identically_tp2() {
    assert_parity(2);
}

/// `prefill_chunk = 0` (the default) must leave the monolithic path
/// byte-identical — the knob's off position is the old engine.
#[test]
fn chunk_knob_off_is_the_monolithic_engine() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let ps = mixed_prompts(4);
    let a = launch_monolithic(1);
    assert!(!a.chunked_prefill_on());
    assert!(a.chunk_ks().is_empty());
    let ea: Vec<Vec<i32>> = ps.iter().map(|p| a.generate(p.clone(), 6).unwrap()).collect();
    a.shutdown();
    let b = Engine::launch(LaunchConfig::preset("tiny").with_prefill_chunk(0, 1)).unwrap();
    assert!(!b.chunked_prefill_on(), "an explicit 0 must also stay off");
    let eb: Vec<Vec<i32>> = ps.iter().map(|p| b.generate(p.clone(), 6).unwrap()).collect();
    b.shutdown();
    assert_eq!(ea, eb);
}

/// Chunked prefill composes with shared-prefix reuse: a chunked
/// registrant's trie entry only goes ready once its crossing chunk has
/// seeded the retained positions, and adopters (whose unmatched suffix
/// may itself be chunked) still stream byte-identically.
#[test]
fn chunking_composes_with_prefix_reuse() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    // a 16-token (2-block) shared template + distinct long suffixes, so
    // the registrant chunks its prefill AND later admissions adopt it
    let template: Vec<i32> = (0..16).map(|i| ((i * 13) % 100 + 1) as i32).collect();
    let ps: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut p = template.clone();
            let len = 5 + (i * 3) % 7;
            p.extend((0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32));
            p
        })
        .collect();
    let off = launch_monolithic(1);
    let expect: Vec<Vec<i32>> =
        ps.iter().map(|p| off.generate(p.clone(), 8).unwrap()).collect();
    off.shutdown();

    let before = kvcache::global_stats();
    let on = Engine::launch(
        LaunchConfig::preset("tiny").with_prefix_cache(true).with_prefill_chunk(CHUNK, 1),
    )
    .unwrap();
    assert!(on.prefix_cache_on() && on.chunked_prefill_on());
    let got: Vec<Vec<i32>> = ps.iter().map(|p| on.generate(p.clone(), 8).unwrap()).collect();
    assert_eq!(got, expect, "chunking + prefix reuse diverged (sequential)");
    let m = on.metrics_snapshot();
    let (hits, misses) = m.prefix_hit_counts();
    assert!(hits > 0, "templated traffic never hit the trie under chunking");
    assert!(misses >= 1, "the donor admission must have missed");
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| on.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "chunking + prefix reuse diverged (concurrent)");
    on.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "shared chunked blocks leaked");
    assert_eq!(after.double_free, before.double_free);
}

/// Chunked prefill over the tiered cache: the admission gate charges the
/// *final* cache length up front, so a chunked session never outgrows
/// its device reservation mid-wave — streams stay byte-identical and
/// both tiers drain to zero.
#[test]
fn chunking_with_spill_tier_stays_exact_and_leaks_nothing() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let ps = mixed_prompts(12);
    let resident = launch_monolithic(1);
    let expect: Vec<Vec<i32>> =
        ps.iter().map(|p| resident.generate(p.clone(), 6).unwrap()).collect();
    resident.shutdown();

    let before = kvcache::global_stats();
    let mut lc = LaunchConfig::preset("tiny").with_kv_spill(10, 0).with_prefill_chunk(CHUNK, 1);
    lc.engine.pool_threads = 2;
    let engine = Engine::launch(lc).unwrap();
    assert!(engine.kv_spill_on() && engine.chunked_prefill_on());
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 6)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "chunked prefill over the spill tier diverged");
    let stats = engine.metrics_snapshot().kvcache_stats();
    assert_eq!(
        stats.gather_spilled, before.gather_spilled,
        "a chunk wave dispatched against a spilled session"
    );
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "device blocks leaked");
    assert_eq!(after.host_bytes, before.host_bytes, "host tier leaked");
    assert_eq!(after.double_free, before.double_free);
}

/// A cancellation wave over long prompts lands while sessions are
/// mid-chunk (queued continuations and in-flight waves alike): survivors
/// must stream byte-identically and every partially-seeded session's
/// blocks must come back.
#[test]
fn cancel_mid_chunk_leaks_nothing_and_spares_survivors() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let ps = mixed_prompts(16);

    let control = launch_monolithic(1);
    let expect: Vec<Vec<i32>> = ps
        .iter()
        .step_by(2)
        .map(|p| control.generate(p.clone(), 6).unwrap())
        .collect();
    control.shutdown();

    let before = kvcache::global_stats();
    let engine = launch_chunked(1);
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 6)).unwrap())
        .collect();
    // hang up every odd client immediately — long prompts are still in
    // (or queued between) their chunk waves
    for g in grefs.iter().skip(1).step_by(2) {
        g.cancel();
    }
    let survivors: Vec<Vec<i32>> =
        grefs.iter().step_by(2).map(|g| g.to_here().unwrap()).collect();
    assert_eq!(survivors, expect, "a cancelled mid-chunk session changed a survivor");
    for g in grefs.iter().skip(1).step_by(2) {
        let _ = g.to_here(); // cancelled or raced-to-done; both fine
    }
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "cancel mid-chunk leaked blocks");
    assert_eq!(after.host_bytes, before.host_bytes);
    assert_eq!(after.double_free, before.double_free, "a chunked session was freed twice");
}

/// Drop faults orphan chunk waves in flight: the watchdog must poison
/// them at its deadline (streams fail rather than hang), survivors keep
/// their exact bytes, and the drain still returns every block.
#[test]
fn watchdog_mid_chunk_poisons_and_drains() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let ps = mixed_prompts(10);
    let control = launch_monolithic(1);
    let expect: Vec<Vec<i32>> =
        ps.iter().map(|p| control.generate(p.clone(), 4).unwrap()).collect();
    control.shutdown();

    let before = kvcache::global_stats();
    let mut lc = LaunchConfig::preset("tiny")
        .with_prefill_chunk(CHUNK, 1)
        .with_faults("drop@every5+2@w0", 7);
    lc.engine.batch_deadline_ms = 100;
    let engine = Engine::launch(lc).unwrap();
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 4)).unwrap())
        .collect();
    let mut poisoned = 0;
    for (g, expected) in grefs.iter().zip(&expect) {
        match g.to_here() {
            Ok(stream) => {
                assert_eq!(&stream, expected, "a survivor of the drop plan diverged");
            }
            Err(e) => {
                assert!(
                    e.to_string().contains("watchdog deadline"),
                    "unexpected error under drop plan: {e:#}"
                );
                poisoned += 1;
            }
        }
    }
    assert!(poisoned > 0, "a drop-every-5th-ticket plan never orphaned a chunk wave");
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "poisoned chunk waves leaked");
    assert_eq!(after.double_free, before.double_free);
}
