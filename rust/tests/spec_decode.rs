//! Speculative decode, differentially: draft-and-verify continuation
//! steps must be invisible in the token streams. Greedy decoding is
//! deterministic, and the verify pass commits exactly the tokens plain
//! decode would have sampled — so any divergence, under any drafter, at
//! any accept rate, is a speculation bug. Checked across tp=1/tp=2 and
//! k∈{2,4}, through stop-token and context-limit truncation mid-window,
//! and with a drafter forced to a 0% accept rate (the worst case must
//! degenerate to plain-decode behaviour with no K/V leak).
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::drafter::{MisdraftDrafter, ReplayDrafter};
use energonai::coordinator::engine::{Engine, GenRequest, GenRef, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use std::sync::Mutex;

/// Serializes every test in this binary: several assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Verify artifacts for (tiny, tp, k) present? When not, the test is a
/// no-op — matching the seed state instead of adding failures.
fn artifacts_ready(tp: usize, k: usize) -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", tp).is_empty()
        && man.has_kv_prefill("tiny", tp)
        && man.verify_points("tiny", tp).iter().any(|&(_, kk)| kk == k);
    if !ok {
        eprintln!("skipping: verify artifacts missing for tiny/tp{tp}/k{k}");
    }
    ok
}

fn launch_plain(tp: usize) -> Engine {
    Engine::launch(LaunchConfig::preset("tiny").with_parallel(tp, 1)).unwrap()
}

/// A speculative engine capped at window `k` (so k∈{2,4} are pinned
/// independently), with the default n-gram drafter unless overridden.
fn spec_config(tp: usize, k: usize) -> LaunchConfig {
    LaunchConfig::preset("tiny").with_parallel(tp, 1).with_speculative(true).with_spec_k(k)
}

fn prompts() -> Vec<Vec<i32>> {
    let mut ps: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect();
    // a repetitive prompt too: the n-gram drafter should do well on it,
    // exercising the accepted-prefix (not just the rejected-tail) path
    ps.push(vec![7, 8, 9, 7, 8, 9, 7, 8]);
    ps
}

/// The acceptance bar: speculative streams byte-identical to plain greedy
/// decode, sequentially and concurrently, with speculation demonstrably
/// engaged.
fn assert_spec_parity(tp: usize, k: usize) {
    if !artifacts_ready(tp, k) {
        return;
    }
    let _guard = stats_guard();
    let plain = launch_plain(tp);
    assert!(plain.kv_cache_on());
    assert!(!plain.speculative_on(), "speculation must be off by default");
    let expect: Vec<Vec<i32>> = prompts()
        .into_iter()
        .map(|p| plain.generate(p, 8).unwrap())
        .collect();
    plain.shutdown();

    let spec = Engine::launch(spec_config(tp, k)).unwrap();
    assert!(
        spec.speculative_on(),
        "verify artifacts present but speculation did not engage (tp={tp}, k={k})"
    );
    assert_eq!(spec.spec_ks().last(), Some(&k), "spec_k cap not honoured");
    // sequential sessions
    let got: Vec<Vec<i32>> = prompts()
        .into_iter()
        .map(|p| spec.generate(p, 8).unwrap())
        .collect();
    assert_eq!(got, expect, "speculative decode diverged (sequential, tp={tp}, k={k})");
    // concurrent sessions: verify buckets coalesce and must still agree
    let grefs: Vec<GenRef> = prompts()
        .into_iter()
        .map(|p| spec.generate_stream(GenRequest::new(p, 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "speculative decode diverged (concurrent, tp={tp}, k={k})");
    let m = spec.metrics_snapshot();
    assert!(m.spec_passes() > 0, "speculation never ran a verify pass: {}", m.summary());
    assert!(
        m.spec_tokens_per_pass().unwrap() >= 1.0,
        "tokens-per-pass below the plain-decode floor: {}",
        m.summary()
    );
    spec.shutdown();
}

#[test]
fn speculative_matches_plain_tp1_k2() {
    assert_spec_parity(1, 2);
}

#[test]
fn speculative_matches_plain_tp1_k4() {
    assert_spec_parity(1, 4);
}

#[test]
fn speculative_matches_plain_tp2_k2() {
    assert_spec_parity(2, 2);
}

#[test]
fn speculative_matches_plain_tp2_k4() {
    assert_spec_parity(2, 4);
}

/// A perfect drafter (replaying the known greedy continuation) commits
/// multiple tokens per pass — the tokens-per-pass > 1 win — while the
/// stream stays byte-identical.
#[test]
fn perfect_drafter_commits_multiple_tokens_per_pass() {
    if !artifacts_ready(1, 4) {
        return;
    }
    let _guard = stats_guard();
    let plain = launch_plain(1);
    let prompt = vec![5, 9, 2];
    let truth = plain.generate(prompt.clone(), 12).unwrap();
    plain.shutdown();

    let mut lc = spec_config(1, 4);
    lc = lc.with_drafter(ReplayDrafter { script: truth.clone() });
    let spec = Engine::launch(lc).unwrap();
    let got = spec.generate(prompt, 12).unwrap();
    assert_eq!(got, truth, "perfect drafter changed the stream");
    let m = spec.metrics_snapshot();
    assert!(
        m.spec_tokens_per_pass().unwrap() > 1.3,
        "perfect drafter should clear 1.3 tokens/pass: {}",
        m.summary()
    );
    assert!(
        m.spec_accept_rate().unwrap() > 0.9,
        "replayed truth should accept ~100%: {}",
        m.summary()
    );
    spec.shutdown();
}

/// Stop-token truncation mid-window: the drafter keeps proposing past the
/// stop token, the verify pass accepts those drafts (they match greedy),
/// but the collector must cut the stream right after the stop token —
/// exactly where plain decode stops.
#[test]
fn stop_token_truncates_mid_window() {
    if !artifacts_ready(1, 4) {
        return;
    }
    let _guard = stats_guard();
    let plain = launch_plain(1);
    let prompt = vec![5, 9, 2];
    let free_run = plain.generate(prompt.clone(), 8).unwrap();
    assert!(free_run.len() > prompt.len() + 1);
    // stop at the second generated token: with k=4 windows the stop lands
    // mid-window rather than on a step boundary
    let stop = free_run[prompt.len() + 1];
    let expect = plain
        .generate_stream(GenRequest::new(prompt.clone(), 8).with_stop(stop))
        .unwrap()
        .to_here()
        .unwrap();
    plain.shutdown();

    // the replay drafter guarantees accepted windows *past* the stop
    let mut lc = spec_config(1, 4);
    lc = lc.with_drafter(ReplayDrafter { script: free_run.clone() });
    let spec = Engine::launch(lc).unwrap();
    let got = spec
        .generate_stream(GenRequest::new(prompt.clone(), 8).with_stop(stop))
        .unwrap()
        .to_here()
        .unwrap();
    assert_eq!(got, expect, "stop-token truncation diverged under speculation");
    assert_eq!(*got.last().unwrap(), stop);
    spec.shutdown();
}

/// Context-limit truncation mid-window: a session whose verify window
/// would run past the longest compiled bucket must stop at exactly the
/// same point as plain decode (the engine shrinks or abandons the window
/// near the limit; the collector applies the same per-token length rule).
#[test]
fn context_limit_truncates_mid_window() {
    if !artifacts_ready(1, 4) {
        return;
    }
    let _guard = stats_guard();
    let plain = launch_plain(1);
    let prompt: Vec<i32> = (1..=27).collect();
    let expect = plain.generate(prompt.clone(), 16).unwrap();
    plain.shutdown();
    // 27 + 16 > 32: the session must stop early at the context limit
    assert!(expect.len() < 27 + 16, "context limit never hit");

    let spec = Engine::launch(spec_config(1, 4)).unwrap();
    let got = spec.generate(prompt, 16).unwrap();
    assert_eq!(got, expect, "context-limit truncation diverged under speculation");
    spec.shutdown();
}

/// The worst case: a drafter forced to 0% accept rate. Every verify pass
/// degenerates to one committed token (plain-decode progress), every
/// speculatively appended K/V row is truncated back out, the stream is
/// unchanged, and no cache blocks leak.
#[test]
fn zero_accept_drafter_degenerates_cleanly() {
    if !artifacts_ready(1, 4) {
        return;
    }
    let _guard = stats_guard();
    let blocks_before = kvcache::global_stats().blocks_in_use;
    let plain = launch_plain(1);
    let vocab = plain.cfg.vocab as i32;
    let ps = prompts();
    let truths: Vec<Vec<i32>> = ps.iter().map(|p| plain.generate(p.clone(), 8).unwrap()).collect();
    plain.shutdown();

    for (p, truth) in ps.into_iter().zip(&truths) {
        let mut lc = spec_config(1, 4);
        lc = lc.with_drafter(MisdraftDrafter { truth: truth.clone(), vocab });
        let spec = Engine::launch(lc).unwrap();
        let got = spec.generate(p, 8).unwrap();
        assert_eq!(&got, truth, "0%-accept drafter changed the stream");
        let m = spec.metrics_snapshot();
        assert!(m.spec_passes() > 0, "{}", m.summary());
        assert_eq!(
            m.spec_accept_rate(),
            Some(0.0),
            "misdrafts must never be accepted: {}",
            m.summary()
        );
        assert!(
            (m.spec_tokens_per_pass().unwrap() - 1.0).abs() < 1e-9,
            "worst case must emit exactly one token per pass: {}",
            m.summary()
        );
        // every rejected window was truncated back out of the cache
        assert!(m.kvcache_stats().truncates > 0, "{}", m.summary());
        spec.shutdown();
    }
    // no KV leak: block counters return to the baseline
    let after = kvcache::global_stats();
    assert_eq!(
        after.blocks_in_use, blocks_before,
        "0%-accept speculation leaked cache blocks"
    );
}

/// Speculation engages the verify path for coalesced concurrent sessions
/// too, and the engine drains cleanly with blocks back on the free lists.
#[test]
fn concurrent_speculative_sessions_release_all_blocks() {
    if !artifacts_ready(1, 4) {
        return;
    }
    let _guard = stats_guard();
    let before = kvcache::global_stats().blocks_in_use;
    let spec = Engine::launch(spec_config(1, 4)).unwrap();
    let grefs: Vec<GenRef> = prompts()
        .into_iter()
        .map(|p| spec.generate_stream(GenRequest::new(p, 6)).unwrap())
        .collect();
    for g in &grefs {
        g.to_here().unwrap();
    }
    let m = spec.metrics_snapshot();
    assert!(m.spec_passes() > 0, "{}", m.summary());
    spec.shutdown();
    let after = kvcache::global_stats().blocks_in_use;
    assert_eq!(after, before, "speculative sessions leaked cache blocks");
}
