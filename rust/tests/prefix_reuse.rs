//! Shared-prefix K/V reuse, differentially.
//!
//! A prefix-cache hit adopts the donor's cached blocks and replays only
//! the unmatched prompt suffix through decode steps — and because the
//! decode path is already byte-pinned against prefill (see
//! `tests/kv_decode.rs`), the feature must be invisible in every stream:
//! on vs off byte-identical at tp=1 and tp=2, divergence after the
//! shared prefix preserved exactly, and zero block leaks after
//! cancellation waves and failure-path (chaos) cascades.
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::engine::{Engine, GenRef, GenRequest, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use std::sync::Mutex;

/// Serializes every test in this binary: several assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_ready(tp: usize) -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", tp).is_empty() && man.has_kv_prefill("tiny", tp);
    if !ok {
        eprintln!("skipping: decode artifacts missing for tiny/tp{tp}");
    }
    ok
}

fn launch(prefix: bool, tp: usize) -> Engine {
    Engine::launch(
        LaunchConfig::preset("tiny")
            .with_parallel(tp, 1)
            .with_prefix_cache(prefix),
    )
    .unwrap()
}

/// Templated prompts: a 16-token (2-block) shared template followed by
/// distinct short suffixes, so admissions after the first can adopt the
/// template's blocks whole.
fn template() -> Vec<i32> {
    (0..16).map(|i| ((i * 13) % 100 + 1) as i32).collect()
}

fn templated_prompts(n: usize) -> Vec<Vec<i32>> {
    let t = template();
    (0..n)
        .map(|i| {
            let mut p = t.clone();
            let len = 2 + (i * 3) % 5;
            p.extend((0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32));
            p
        })
        .collect()
}

/// The acceptance bar: with the prefix cache on, templated traffic emits
/// byte-identical token streams to the off engine — sequentially (every
/// admission after the donor is a trie hit) and concurrently — while
/// actually taking the adoption path.
fn assert_parity(tp: usize) {
    if !artifacts_ready(tp) {
        return;
    }
    let _guard = stats_guard();
    let ps = templated_prompts(6);
    let off = launch(false, tp);
    assert!(!off.prefix_cache_on(), "prefix_cache(false) must stay off");
    let expect: Vec<Vec<i32>> = ps.iter().map(|p| off.generate(p.clone(), 8).unwrap()).collect();
    off.shutdown();

    let before = kvcache::global_stats();
    let on = launch(true, tp);
    assert!(on.prefix_cache_on(), "kv decode live but prefix cache not on");
    // sequential: the first prompt registers, every later one can hit
    let got: Vec<Vec<i32>> = ps.iter().map(|p| on.generate(p.clone(), 8).unwrap()).collect();
    assert_eq!(got, expect, "prefix reuse diverged (sequential, tp={tp})");
    let m = on.metrics_snapshot();
    let (hits, misses) = m.prefix_hit_counts();
    assert!(hits > 0, "templated sequential traffic never hit the trie (tp={tp})");
    assert!(misses >= 1, "the donor admission must have missed");
    assert!(
        m.kvcache_stats().adopted_blocks > 0,
        "trie hits must adopt worker-side blocks"
    );
    // concurrent: queued hits, stepping decodes and fresh prefills coalesce
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| on.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    let got: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    assert_eq!(got, expect, "prefix reuse diverged (concurrent, tp={tp})");
    on.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(
        after.blocks_in_use, before.blocks_in_use,
        "prefix registry leaked blocks across shutdown (tp={tp})"
    );
    assert_eq!(after.double_free, before.double_free, "a shared block was freed twice");
}

#[test]
fn prefix_on_matches_off_byte_identically_tp1() {
    assert_parity(1);
}

#[test]
fn prefix_on_matches_off_byte_identically_tp2() {
    assert_parity(2);
}

/// Sessions that share a prefix then diverge must diverge exactly as the
/// off engine says: the adopter's continuation writes go to its own
/// (copy-on-write) tail, never the donor's — and an adopter with the
/// donor's identical prompt replays the donor's stream.
#[test]
fn divergence_after_shared_prefix_is_exact() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let t = template();
    let mut a = t.clone();
    a.extend([7, 9]);
    let mut b = t.clone();
    b.extend([42, 3, 5]);
    let off = launch(false, 1);
    let ea = off.generate(a.clone(), 8).unwrap();
    let eb = off.generate(b.clone(), 8).unwrap();
    off.shutdown();

    let on = launch(true, 1);
    // donor, then two adopters that fork after block 2
    let ga = on.generate(a.clone(), 8).unwrap();
    let gb = on.generate(b.clone(), 8).unwrap();
    assert_eq!(ga, ea, "donor stream changed");
    assert_eq!(gb, eb, "post-divergence stream corrupted by shared blocks");
    // an identical re-submission is a hit on the full shared span and
    // must replay the donor byte-for-byte (greedy decode is deterministic)
    let ga2 = on.generate(a.clone(), 8).unwrap();
    assert_eq!(ga2, ea, "identical prompt after a hit diverged");
    let (hits, _) = on.metrics_snapshot().prefix_hit_counts();
    assert!(hits >= 2, "both re-admissions should have hit, saw {hits}");
    on.shutdown();
}

/// The refcount invariant under the failure paths: a cancellation wave
/// over templated traffic (queued, stepping and in-flight sessions
/// alike) plus a chaos panic plan must leave survivors byte-identical
/// and return every block — shared or private — on shutdown.
#[test]
fn cancel_wave_and_chaos_leak_nothing_with_prefix_on() {
    if !artifacts_ready(1) {
        return;
    }
    let _guard = stats_guard();
    let ps = templated_prompts(16);

    // control: the survivors' prompts through a prefix-off engine
    let control = launch(false, 1);
    let expect: Vec<Vec<i32>> = ps
        .iter()
        .step_by(2)
        .map(|p| control.generate(p.clone(), 6).unwrap())
        .collect();
    control.shutdown();

    // cancellation wave
    let before = kvcache::global_stats();
    let engine = launch(true, 1);
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 6)).unwrap())
        .collect();
    for g in grefs.iter().skip(1).step_by(2) {
        g.cancel();
    }
    let survivors: Vec<Vec<i32>> = grefs.iter().step_by(2).map(|g| g.to_here().unwrap()).collect();
    assert_eq!(survivors, expect, "a cancelled adopter changed a survivor's stream");
    for g in grefs.iter().skip(1).step_by(2) {
        let _ = g.to_here(); // cancelled or raced-to-done; both fine
    }
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "cancel wave leaked shared blocks");
    assert_eq!(after.host_bytes, before.host_bytes);
    assert_eq!(after.double_free, before.double_free, "a shared block was freed twice");

    // chaos: every 4th batch panics — failed registrants must drop their
    // trie entries (never go ready without a retention), survivors stream
    // exactly, and the registry still drains on shutdown
    let before = kvcache::global_stats();
    let engine = Engine::launch(
        LaunchConfig::preset("tiny")
            .with_prefix_cache(true)
            .with_faults("panic@every4+0", 7),
    )
    .unwrap();
    let grefs: Vec<GenRef> = ps
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 6)).unwrap())
        .collect();
    let mut failed = 0;
    for (g, p) in grefs.iter().zip(&ps) {
        match g.to_here() {
            Ok(stream) => {
                assert_eq!(&stream[..p.len()], &p[..], "stream lost its prompt");
            }
            Err(e) => {
                assert!(
                    e.to_string().contains("injected worker fault")
                        || e.to_string().contains("watchdog"),
                    "unexpected error under panic plan: {e:#}"
                );
                failed += 1;
            }
        }
    }
    assert!(failed > 0, "a panic-every-4th-ticket plan never fired across 16 sessions");
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "chaos cascade leaked shared blocks");
    assert_eq!(after.double_free, before.double_free);
}
