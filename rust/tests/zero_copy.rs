//! Zero-copy hot-path guarantees (§Perf):
//!
//! 1. Differential: the scratch-reusing `ring_allreduce`, `broadcast` and
//!    DRCE `pack`/`unpack` are **bit-exact** against the pre-arena
//!    allocating reference implementations across uneven chunk sizes,
//!    empty chunks, and repeated reuse of the same scratch buffers.
//! 2. Steady state: `ring_allreduce` performs **zero heap allocations per
//!    call** after warmup, asserted through the `metrics::Recorder` arena
//!    allocation counters (fed from per-thread arena stats, so parallel
//!    tests cannot perturb the assertion).

use energonai::comm::channel::{CommWorld, Mode};
use energonai::comm::collective::{broadcast, reference, ring_allreduce, ChunkMsg};
use energonai::memory::arena::ArenaPool;
use energonai::metrics::Recorder;
use energonai::tensor::{drce, Tensor};
use energonai::util::rng::Rng;
use std::thread;

/// Run one collective on every rank of a fresh world; collect per-rank
/// outputs in rank order.
fn run_world<F>(n: usize, f: F) -> Vec<Tensor>
where
    F: Fn(energonai::comm::channel::Endpoint<ChunkMsg>, Vec<usize>) -> Tensor + Send + Sync + 'static + Clone,
{
    let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
    let group: Vec<usize> = (0..n).collect();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|ep| {
            let group = group.clone();
            let f = f.clone();
            thread::spawn(move || f(ep, group))
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn rank_input(rank: usize, len: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed * 1000 + rank as u64);
    Tensor::randn(&[len], 1.0, &mut rng)
}

#[test]
fn allreduce_matches_reference_bit_exactly() {
    // uneven chunks (len % n != 0), empty chunks (len < n), single-element
    for n in [2usize, 3, 4] {
        for len in [1usize, 2, 3, 7, 10, 64, 130, 1000] {
            let arena_out = run_world(n, move |ep, group| {
                let t = rank_input(ep.rank, len, 42);
                ring_allreduce(&ep, &group, t)
            });
            let ref_out = run_world(n, move |ep, group| {
                let t = rank_input(ep.rank, len, 42);
                reference::ring_allreduce(&ep, &group, t)
            });
            for (rank, (a, r)) in arena_out.iter().zip(&ref_out).enumerate() {
                assert!(
                    a.data == r.data,
                    "allreduce mismatch: n={n} len={len} rank={rank}"
                );
            }
        }
    }
}

#[test]
fn allreduce_reuses_scratch_across_repeated_calls() {
    // repeated calls through the same endpoints must stay bit-exact while
    // the arena recycles the same chunk buffers underneath
    let n = 3;
    let len = 130;
    let outs = run_world(n, move |ep, group| {
        let mut t = rank_input(ep.rank, len, 7);
        for _ in 0..8 {
            t = ring_allreduce(&ep, &group, t);
        }
        t
    });
    let refs = run_world(n, move |ep, group| {
        let mut t = rank_input(ep.rank, len, 7);
        for _ in 0..8 {
            t = reference::ring_allreduce(&ep, &group, t);
        }
        t
    });
    for (a, r) in outs.iter().zip(&refs) {
        assert!(a.data == r.data, "repeated-call divergence");
    }
}

#[test]
fn broadcast_matches_reference_with_many_receivers() {
    for n in [3usize, 4, 5] {
        let arena_out = run_world(n, move |ep, group| {
            let t = (ep.rank == 0).then(|| rank_input(0, 257, 11));
            broadcast(&ep, &group, 0, t)
        });
        let ref_out = run_world(n, move |ep, group| {
            let t = (ep.rank == 0).then(|| rank_input(0, 257, 11));
            reference::broadcast(&ep, &group, 0, t)
        });
        for (rank, (a, r)) in arena_out.iter().zip(&ref_out).enumerate() {
            assert!(a.data == r.data, "broadcast mismatch: n={n} rank={rank}");
        }
    }
}

#[test]
fn drce_pack_unpack_match_reference_across_scratch_reuse() {
    let mut rng = Rng::new(5);
    let seq = 16;
    for lens in [vec![9usize, 16, 3, 1], vec![16; 4], vec![2], vec![8, 0, 8]] {
        let total: usize = lens.iter().sum();
        let bucket = total.next_power_of_two().max(16);
        let maps = drce::make_maps(&lens, seq, bucket).unwrap();
        let h = 32;
        // the same scratch tensors are reused for every iteration — stale
        // contents from the previous batch must never leak through
        let mut packed_scratch = Tensor::pooled_uninit(&[bucket, h]);
        let mut padded_scratch = Tensor::pooled_uninit(&[lens.len() * seq, h]);
        for _ in 0..4 {
            let x = Tensor::randn(&[lens.len() * seq, h], 1.0, &mut rng);
            let want_packed = drce::reference::pack(&x, &maps);
            drce::pack_into(&x, &maps, &mut packed_scratch);
            assert!(packed_scratch == want_packed, "pack_into mismatch {lens:?}");
            assert!(drce::pack(&x, &maps) == want_packed, "pack mismatch {lens:?}");
            let want_padded = drce::reference::unpack(&want_packed, &maps);
            drce::unpack_into(&packed_scratch, &maps, &mut padded_scratch);
            assert!(padded_scratch == want_padded, "unpack_into mismatch {lens:?}");
            assert!(drce::unpack(&want_packed, &maps) == want_padded, "unpack mismatch {lens:?}");
        }
    }
}

#[test]
fn steady_state_allreduce_is_allocation_free() {
    // Each rank: warm up the ring, snapshot its thread-local arena stats
    // into a Recorder, run many more calls, and assert via the Recorder
    // counters that not a single fresh heap allocation happened.
    let n = 4;
    let len = 64 * 1024;
    let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
    let group: Vec<usize> = (0..n).collect();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|ep| {
            let group = group.clone();
            thread::spawn(move || {
                let mut t = Tensor::full(&[len], ep.rank as f32);
                // warmup: populate this thread's arena shelf
                for _ in 0..3 {
                    t = ring_allreduce(&ep, &group, t);
                }
                let mut rec = Recorder::new();
                rec.record_arena(ArenaPool::thread_stats());
                let before = rec.arena_stats();
                let iters: usize = 20;
                for _ in 0..iters {
                    t = ring_allreduce(&ep, &group, t);
                }
                rec.record_arena(ArenaPool::thread_stats());
                let after = rec.arena_stats();
                assert_eq!(
                    after.fresh_allocs, before.fresh_allocs,
                    "rank {}: steady-state ring_allreduce allocated",
                    ep.rank
                );
                // every chunk checkout was served from the shelf: 2(n-1)
                // non-empty chunks per call
                let expect_reuses = (iters * 2 * (n - 1)) as u64;
                assert!(
                    after.reuses - before.reuses >= expect_reuses,
                    "rank {}: expected ≥{expect_reuses} reuses, got {}",
                    ep.rank,
                    after.reuses - before.reuses
                );
                assert!(after.bytes_recycled > before.bytes_recycled);
                t
            })
        })
        .collect();
    for h in handles {
        let out = h.join().unwrap();
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
}
