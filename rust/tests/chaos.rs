//! Fail-safe serving under hostile traffic, differentially: cancellation
//! propagation (no leaked K/V blocks, no wasted decode work), load
//! shedding with structured `Busy` rejections, chaos fault injection
//! (delay / drop / panic at the worker reply boundary), and the seeded
//! saturation scenario from the acceptance bar — 25% mid-stream
//! disconnects plus an injected worker stall, with survivor streams
//! byte-identical to an unfaulted control run.
//!
//! Every test skips cleanly when the AOT artifacts are absent (the same
//! condition under which an `Engine` cannot launch at all), so the suite
//! never *adds* failures on an artifact-less checkout.

use energonai::coordinator::engine::{Engine, GenRef, GenRequest, LaunchConfig};
use energonai::coordinator::Busy;
use energonai::memory::kvcache;
use energonai::runtime::{find_artifacts, Manifest};
use energonai::workload::loadgen::{
    parity_mismatches, run_saturation, Outcome, SaturationScenario,
};
use std::sync::Mutex;

/// Serializes every test in this binary: several assert on the
/// process-wide kvcache gauges, so no other engine may run concurrently.
static STATS_LOCK: Mutex<()> = Mutex::new(());

fn stats_guard() -> std::sync::MutexGuard<'static, ()> {
    STATS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_ready() -> bool {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
            return false;
        }
    };
    let man = match Manifest::cached(dir) {
        Ok(m) => m,
        Err(_) => return false,
    };
    let ok = !man.decode_widths("tiny", 1).is_empty() && man.has_kv_prefill("tiny", 1);
    if !ok {
        eprintln!("skipping: decode artifacts missing for tiny/tp1");
    }
    ok
}

fn launch(cfg: LaunchConfig) -> Engine {
    Engine::launch(cfg).unwrap()
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// Longest compiled prefill bucket for the tiny preset — the context cap
/// the load generator must respect.
fn max_context(engine: &Engine) -> usize {
    engine.manifest.shape_points("tiny").iter().map(|&(_, s)| s).max().unwrap()
}

/// Cancelling sessions mid-generation (the client-side half of a TCP
/// disconnect) must end their streams with a `cancelled` error, leave
/// survivor streams byte-identical to a cancel-free control run, and
/// free every K/V block on shutdown.
#[test]
fn cancel_mid_generation_leaks_nothing_and_spares_survivors() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let all = prompts(16);

    // control: the survivors' prompts, no cancellations anywhere
    let control = launch(LaunchConfig::preset("tiny"));
    let expect: Vec<Vec<i32>> = all
        .iter()
        .step_by(2)
        .map(|p| control.generate(p.clone(), 8).unwrap())
        .collect();
    control.shutdown();

    let before = kvcache::global_stats();
    let engine = launch(LaunchConfig::preset("tiny"));
    let grefs: Vec<GenRef> = all
        .iter()
        .map(|p| engine.generate_stream(GenRequest::new(p.clone(), 8)).unwrap())
        .collect();
    // hang up every odd-indexed client immediately (its session may be
    // queued or already in flight — both paths must reclaim)
    for g in grefs.iter().skip(1).step_by(2) {
        g.cancel();
    }
    let survivors: Vec<Vec<i32>> =
        grefs.iter().step_by(2).map(|g| g.to_here().unwrap()).collect();
    assert_eq!(survivors, expect, "a cancelled neighbour changed a survivor's stream");
    let mut cancelled_seen = 0;
    for g in grefs.iter().skip(1).step_by(2) {
        match g.to_here() {
            Err(e) => {
                assert!(e.to_string().contains("cancelled"), "unexpected error: {e:#}");
                assert!(g.is_cancelled());
                cancelled_seen += 1;
            }
            // the generation won the race and completed before the
            // cancel landed — legal, just not the interesting path
            Ok(_) => assert!(!g.is_cancelled()),
        }
    }
    assert!(cancelled_seen > 0, "all 8 cancels lost the race to 8-token generations");
    // engine-side accounting: a cancel observed by the client was either
    // purged from the queue or doomed in flight (a session can, rarely,
    // retire between the client's cancel and the former's sweep, so exact
    // equality is not guaranteed — but zero means propagation is broken)
    let metrics = engine.metrics_snapshot();
    assert!(metrics.cancelled() > 0, "no cancel ever reached the engine");
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "cancelled sessions leaked blocks");
    assert_eq!(after.host_bytes, before.host_bytes);
    assert_eq!(after.double_free, before.double_free, "a session was released twice");
}

/// With a queued-prefill depth cap, a submission wave past capacity gets
/// structured `Busy` rejections (downcastable, with the queue depth)
/// instead of unbounded queueing — and everything admitted completes.
#[test]
fn queue_cap_sheds_with_structured_busy() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let before = kvcache::global_stats();
    let mut lc = LaunchConfig::preset("tiny").with_admission(1, 0);
    lc.engine.pool_threads = 1;
    let engine = launch(lc);
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for p in prompts(24) {
        match engine.generate_stream(GenRequest::new(p, 4)) {
            Ok(g) => admitted.push(g),
            Err(e) => {
                let b = e.downcast_ref::<Busy>().expect("rejection must downcast to Busy");
                assert_eq!(b.reason, "queue-full");
                assert!(b.queued >= 1);
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "24 rapid submissions never tripped a depth cap of 1");
    assert!(!admitted.is_empty(), "the cap must shed, not blackhole");
    for g in &admitted {
        g.to_here().unwrap();
    }
    let metrics = engine.metrics_snapshot();
    assert_eq!(metrics.shed(), shed);
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "shed requests leaked blocks");
}

/// A delay fault stalls replies without changing them: streams stay
/// byte-identical to the unfaulted run, nothing leaks, shutdown drains.
#[test]
fn delay_fault_changes_latency_not_bytes() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let ps = prompts(6);
    let clean = launch(LaunchConfig::preset("tiny"));
    let expect: Vec<Vec<i32>> =
        ps.iter().map(|p| clean.generate(p.clone(), 6).unwrap()).collect();
    clean.shutdown();

    let before = kvcache::global_stats();
    let engine = launch(LaunchConfig::preset("tiny").with_faults("delay2ms@every3+1", 7));
    let got: Vec<Vec<i32>> =
        ps.iter().map(|p| engine.generate(p.clone(), 6).unwrap()).collect();
    assert_eq!(got, expect, "a delay fault must never change a stream");
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use);
}

/// Panic faults fail their batches loudly: the affected sessions' streams
/// error with the injected message, the engine keeps serving, and every
/// faulted session's blocks are reclaimed.
#[test]
fn panic_fault_fails_batches_without_leaking() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let before = kvcache::global_stats();
    let engine = launch(LaunchConfig::preset("tiny").with_faults("panic@every4+0", 7));
    let grefs: Vec<GenRef> = prompts(12)
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, 6)).unwrap())
        .collect();
    let mut failed = 0;
    for g in &grefs {
        match g.to_here() {
            Ok(_) => {}
            Err(e) => {
                assert!(
                    e.to_string().contains("injected worker fault"),
                    "unexpected error under panic plan: {e:#}"
                );
                failed += 1;
            }
        }
    }
    assert!(failed > 0, "a panic-every-4th-ticket plan never fired across 12 sessions");
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "faulted sessions leaked blocks");
    assert_eq!(after.double_free, before.double_free);
}

/// Drop faults suppress replies entirely: the watchdog must poison the
/// orphaned batches at its deadline (streams fail rather than hang) and
/// shutdown must still drain within it.
#[test]
fn drop_fault_is_poisoned_by_the_watchdog_and_drains() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();
    let before = kvcache::global_stats();
    let mut lc = LaunchConfig::preset("tiny").with_faults("drop@every5+2@w0", 7);
    lc.engine.batch_deadline_ms = 100;
    let engine = launch(lc);
    let grefs: Vec<GenRef> = prompts(10)
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, 4)).unwrap())
        .collect();
    let mut poisoned = 0;
    for g in &grefs {
        match g.to_here() {
            Ok(_) => {}
            Err(e) => {
                assert!(
                    e.to_string().contains("watchdog deadline"),
                    "unexpected error under drop plan: {e:#}"
                );
                poisoned += 1;
            }
        }
    }
    assert!(poisoned > 0, "a drop-every-5th-ticket plan never orphaned a batch");
    // the drain must terminate despite the dropped replies — the watchdog
    // is what bounds it; a hang here is the regression
    engine.shutdown();
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "poisoned batches leaked blocks");
}

/// The acceptance scenario: seeded saturation with 25% mid-stream
/// disconnects and an injected worker stall, against an engine with
/// admission control. The engine must shed (not queue unboundedly),
/// leak nothing on either tier, keep survivor streams byte-identical to
/// the unfaulted control run, and drain shutdown cleanly.
#[test]
fn saturation_with_disconnects_and_a_stall_sheds_and_leaks_nothing() {
    if !artifacts_ready() {
        return;
    }
    let _guard = stats_guard();

    // control: same seed, no disconnects, no faults, no admission caps —
    // every stream completes, forming the parity reference
    let control_engine = launch(LaunchConfig::preset("tiny"));
    let cap = max_context(&control_engine);
    let control = run_saturation(
        &control_engine,
        &SaturationScenario::new(2209, 10, 3),
        cap,
    );
    control_engine.shutdown();
    assert_eq!(control.disconnected, 0);
    assert_eq!(control.errors, 0, "control run must be clean: {:?}", control.streams);

    let before = kvcache::global_stats();
    let mut lc = LaunchConfig::preset("tiny")
        .with_admission(2, 0)
        .with_faults("delay3ms@t6..9", 2209);
    lc.engine.pool_threads = 2;
    let engine = launch(lc);
    let report = run_saturation(
        &engine,
        &SaturationScenario::new(2209, 10, 3).with_disconnects(0.25),
        cap,
    );
    let metrics = engine.metrics_snapshot();
    engine.shutdown();

    assert!(report.disconnected > 0, "the 25% chaos stream never fired");
    assert!(
        report.streams.iter().any(|s| s.outcome == Outcome::Completed),
        "nothing survived the scenario"
    );
    assert_eq!(
        report.errors,
        0,
        "delay faults and disconnects must not hard-fail streams: {:?}",
        report
            .streams
            .iter()
            .filter(|s| matches!(s.outcome, Outcome::Error(_)))
            .collect::<Vec<_>>()
    );
    // clients now retry Busy with backoff, so the engine-side shed count
    // equals *rejections observed* (including retries that later got in),
    // while `report.shed` counts only the turns that gave up
    assert_eq!(metrics.shed(), report.busy_rejections as u64);
    assert!(report.busy_rejections >= report.shed);
    assert!(metrics.cancelled() > 0, "disconnects must propagate to the engine");

    // survivor parity: chaos may change *which* streams finish, never
    // *what* a finished stream says
    let diffs = parity_mismatches(&control, &report);
    assert!(diffs.is_empty(), "survivor streams diverged:\n{}", diffs.join("\n"));

    // leaked blocks == 0, on both tiers, after the drain
    let after = kvcache::global_stats();
    assert_eq!(after.blocks_in_use, before.blocks_in_use, "saturation leaked device blocks");
    assert_eq!(after.host_bytes, before.host_bytes, "saturation leaked host bytes");
    assert_eq!(after.double_free, before.double_free, "a session was released twice");
}
