//! Hot-path microbenchmarks — the profile targets of the §Perf pass:
//! engine publish→complete round trip, ring all-reduce, DRCE pack/unpack,
//! batcher formation, manifest parsing, and bare PJRT layer execution.

use energonai::comm::channel::{CommWorld, Mode};
use energonai::comm::collective::{ring_allreduce, ChunkMsg};
use energonai::config::ModelConfig;
use energonai::coordinator::batcher::{Batcher, Request};
use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::model::ModelWeights;
use energonai::runtime::{find_artifacts, valid_len_arg, Device, Manifest};
use energonai::tensor::{drce, Tensor, Value};
use energonai::util::bench::run_print;
use energonai::util::rng::Rng;
use std::time::Duration;

fn bench_engine_roundtrip() {
    let engine = Engine::launch(LaunchConfig::preset("tiny").with_warmup(true)).unwrap();
    run_print("engine publish→complete (tiny, 1 worker)", 5, 50, || {
        let r = engine
            .infer_batch(vec![Request::new(0, vec![7; 10])])
            .unwrap();
        r.to_here().unwrap();
    });
    engine.shutdown();
}

fn bench_bare_layer() {
    let man = Manifest::load(find_artifacts().unwrap()).unwrap();
    let dev = Device::new(0).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let w = ModelWeights::random(&cfg, 1);
    let v = man.get("tiny_layer_full_b2_s16").unwrap();
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[2, 16, cfg.hidden], 0.5, &mut rng);
    let mut args = vec![Value::F32(x), valid_len_arg(&[16, 16])];
    args.extend(w.layers[0].all_args());
    dev.execute(&man, v, &args).unwrap();
    run_print("bare PJRT layer_full execute (tiny b2 s16)", 5, 50, || {
        dev.execute(&man, v, &args).unwrap();
    });
}

fn bench_allreduce() {
    for n in [2usize, 4] {
        let len = 262_144; // 1 MiB of f32
        let stats = {
            let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
            let group: Vec<usize> = (0..n).collect();
            let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
            let handles: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    let group = group.clone();
                    let barrier = barrier.clone();
                    std::thread::spawn(move || {
                        let t = Tensor::full(&[len], ep.rank as f32);
                        let mut out = None;
                        let iters = 30;
                        barrier.wait();
                        let t0 = std::time::Instant::now();
                        for _ in 0..iters {
                            out = Some(ring_allreduce(&ep, &group, t.clone()));
                        }
                        let el = t0.elapsed() / iters;
                        (el, out.unwrap().data[0])
                    })
                })
                .collect();
            let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            results[0].0
        };
        println!(
            "ring all-reduce 1MiB x{n} ranks                     med {:>10}",
            energonai::util::fmt_duration(stats)
        );
    }
}

fn bench_drce_pack() {
    let maps = drce::make_maps(&[32; 4], 64, 128).unwrap();
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&[256, 256], 0.5, &mut rng);
    run_print("drce pack 256x256 (valid=pad/2)", 10, 200, || {
        std::hint::black_box(drce::pack(&x, &maps));
    });
    let packed = drce::pack(&x, &maps);
    run_print("drce unpack 128->256 rows", 10, 200, || {
        std::hint::black_box(drce::unpack(&packed, &maps));
    });
}

fn bench_batcher() {
    run_print("batcher form 64 reqs into buckets", 5, 100, || {
        let mut b = Batcher::new(vec![(1, 16), (2, 16), (4, 32)], 4, Duration::ZERO);
        for i in 0..64 {
            b.push(Request::new(i, vec![1; (i as usize % 14) + 1])).unwrap();
        }
        std::hint::black_box(b.flush());
    });
}

fn bench_manifest() {
    let dir = find_artifacts().unwrap();
    run_print("manifest.json parse (full plan)", 2, 50, || {
        std::hint::black_box(Manifest::load(&dir).unwrap());
    });
}

fn main() {
    println!("hot-path microbenchmarks (see EXPERIMENTS.md §Perf):");
    bench_bare_layer();
    bench_engine_roundtrip();
    bench_allreduce();
    bench_drce_pack();
    bench_batcher();
    bench_manifest();
}
