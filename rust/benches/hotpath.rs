//! Hot-path microbenchmarks — the profile targets of the §Perf pass:
//! engine publish→complete round trip, ring all-reduce, DRCE pack/unpack,
//! batcher formation, manifest parsing, and bare PJRT layer execution.
//!
//! For every hot path touched by the zero-copy refactor the bench runs the
//! allocating *reference* implementation next to the arena implementation
//! and prints both, so regressions show up as a before/after pair. Medians
//! are also written machine-readably to `BENCH_hotpath.json` at the repo
//! root (regenerate with `scripts/bench_hotpath.sh`) so later PRs can
//! track the perf trajectory.

use energonai::comm::channel::{CommWorld, Mode};
use energonai::comm::collective::{self, ring_allreduce, ChunkMsg};
use energonai::config::ModelConfig;
use energonai::coordinator::batcher::{Batcher, Request};
use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::model::ModelWeights;
use energonai::runtime::{find_artifacts, valid_len_arg, Device, Manifest};
use energonai::tensor::{drce, Tensor, Value};
use energonai::util::bench::run_print;
use energonai::util::rng::Rng;
use std::time::Duration;

/// (metric name, median µs) pairs destined for BENCH_hotpath.json.
type Results = Vec<(String, f64)>;

fn record(results: &mut Results, key: &str, stats: energonai::util::bench::Stats) {
    results.push((key.to_string(), stats.median.as_secs_f64() * 1e6));
}

fn bench_engine_roundtrip(results: &mut Results) {
    let engine = Engine::launch(LaunchConfig::preset("tiny").with_warmup(true)).unwrap();
    let s = run_print("engine publish→complete (tiny, 1 worker)", 5, 50, || {
        let r = engine
            .infer_batch(vec![Request::new(0, vec![7; 10])])
            .unwrap();
        r.to_here().unwrap();
    });
    record(results, "engine_publish_complete_us", s);
    println!("  {}", engine.metrics_snapshot().summary());
    engine.shutdown();
}

fn bench_bare_layer(results: &mut Results) {
    let man = Manifest::load(find_artifacts().unwrap()).unwrap();
    let dev = Device::new(0).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let w = ModelWeights::random(&cfg, 1);
    let v = man.get("tiny_layer_full_b2_s16").unwrap();
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[2, 16, cfg.hidden], 0.5, &mut rng);
    let mut args = vec![Value::F32(x), valid_len_arg(&[16, 16])];
    args.extend(w.layers[0].all_args());
    dev.execute(&man, v, &args).unwrap();
    let s = run_print("bare PJRT layer_full execute (tiny b2 s16)", 5, 50, || {
        dev.execute(&man, v, &args).unwrap();
    });
    record(results, "bare_layer_execute_us", s);
}

/// One timed all-reduce configuration: every rank loops `iters` calls,
/// feeding the output back in (arena steady state). Each call is timed
/// individually on rank 0 (the ring lock-steps all ranks anyway) and the
/// **median** per-call duration is reported, matching the `median_us` unit
/// of every other entry in BENCH_hotpath.json.
fn time_allreduce(n: usize, len: usize, iters: usize, use_reference: bool) -> Duration {
    let eps = CommWorld::new::<ChunkMsg>(n, Mode::NonBlocking);
    let group: Vec<usize> = (0..n).collect();
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
    let handles: Vec<_> = eps
        .into_iter()
        .map(|ep| {
            let group = group.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut t = Tensor::full(&[len], 1.0);
                // warmup (also populates arena shelves)
                for _ in 0..3 {
                    t = if use_reference {
                        collective::reference::ring_allreduce(&ep, &group, t)
                    } else {
                        ring_allreduce(&ep, &group, t)
                    };
                }
                barrier.wait();
                let mut samples = Vec::with_capacity(iters);
                for _ in 0..iters {
                    let t0 = std::time::Instant::now();
                    t = if use_reference {
                        collective::reference::ring_allreduce(&ep, &group, t)
                    } else {
                        ring_allreduce(&ep, &group, t)
                    };
                    samples.push(t0.elapsed());
                }
                std::hint::black_box(t.data[0]);
                energonai::util::median(samples)
            })
        })
        .collect();
    let medians: Vec<Duration> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    medians[0]
}

fn bench_allreduce(results: &mut Results) {
    for n in [2usize, 4] {
        let len = 262_144; // 1 MiB of f32
        let iters = 30;
        let before = time_allreduce(n, len, iters, true);
        let after = time_allreduce(n, len, iters, false);
        println!(
            "ring all-reduce 1MiB x{n} ranks       reference {:>10}   arena {:>10}",
            energonai::util::fmt_duration(before),
            energonai::util::fmt_duration(after),
        );
        results.push((format!("ring_allreduce_1mib_x{n}_reference_us"), before.as_secs_f64() * 1e6));
        results.push((format!("ring_allreduce_1mib_x{n}_us"), after.as_secs_f64() * 1e6));
    }
}

fn bench_drce_pack(results: &mut Results) {
    let maps = drce::make_maps(&[32; 4], 64, 128).unwrap();
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&[256, 256], 0.5, &mut rng);
    let s = run_print("drce pack 256x256 reference (alloc)", 10, 200, || {
        std::hint::black_box(drce::reference::pack(&x, &maps));
    });
    record(results, "drce_pack_reference_us", s);
    let s = run_print("drce pack 256x256 arena (valid=pad/2)", 10, 200, || {
        std::hint::black_box(drce::pack(&x, &maps));
    });
    record(results, "drce_pack_us", s);
    let packed = drce::pack(&x, &maps);
    let s = run_print("drce unpack 128->256 rows reference", 10, 200, || {
        std::hint::black_box(drce::reference::unpack(&packed, &maps));
    });
    record(results, "drce_unpack_reference_us", s);
    let s = run_print("drce unpack 128->256 rows arena", 10, 200, || {
        std::hint::black_box(drce::unpack(&packed, &maps));
    });
    record(results, "drce_unpack_us", s);
}

fn bench_batcher(results: &mut Results) {
    let s = run_print("batcher form 64 reqs into buckets", 5, 100, || {
        let mut b = Batcher::new(vec![(1, 16), (2, 16), (4, 32)], 4, Duration::ZERO);
        for i in 0..64 {
            b.push(Request::new(i, vec![1; (i as usize % 14) + 1])).unwrap();
        }
        std::hint::black_box(b.flush());
    });
    record(results, "batcher_form_64_us", s);
}

fn bench_manifest(results: &mut Results) {
    let dir = find_artifacts().unwrap();
    let s = run_print("manifest.json parse (full plan)", 2, 50, || {
        std::hint::black_box(Manifest::load(&dir).unwrap());
    });
    record(results, "manifest_parse_us", s);
    // the memoized path engines/tests/benches actually take (§Perf):
    // one parse per path per process, then an Arc clone
    let s = run_print("manifest cached lookup", 2, 50, || {
        std::hint::black_box(Manifest::cached(&dir).unwrap());
    });
    record(results, "manifest_cached_us", s);
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    let arena = energonai::memory::arena::ArenaPool::global_stats();
    let mut body = String::from("{\n  \"schema\": \"bench_hotpath/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_hotpath.sh\",\n");
    body.push_str("  \"unit\": \"median_us\",\n");
    body.push_str(&format!(
        "  \"arena\": {{\"fresh_allocs\": {}, \"reuses\": {}, \"bytes_recycled\": {}}},\n",
        arena.fresh_allocs, arena.reuses, arena.bytes_recycled
    ));
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    println!("hot-path microbenchmarks (see EXPERIMENTS.md §Perf):");
    let mut results: Results = Vec::new();
    let have_artifacts = find_artifacts().is_ok();
    if have_artifacts {
        bench_bare_layer(&mut results);
        bench_engine_roundtrip(&mut results);
    } else {
        println!("(no artifacts found — skipping engine/PJRT benches; run `make artifacts`)");
    }
    bench_allreduce(&mut results);
    bench_drce_pack(&mut results);
    bench_batcher(&mut results);
    if have_artifacts {
        bench_manifest(&mut results);
    }
    write_json(&results);
}
