//! Fig. 11 — pipeline parallelism scalability (EnergonAI NBPP vs
//! FasterTransformer blocking send/recv), plus a live grounding run: the
//! same pipeline code with buffered vs rendezvous channels on real PJRT
//! execution, streaming a window of batches like the paper's throughput
//! measurement.

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::coordinator::Request;
use energonai::sim::report;
use std::time::Instant;

fn live_pp(blocking: bool) {
    let engine = Engine::launch(
        LaunchConfig::preset("tiny")
            .with_parallel(1, 2)
            .with_blocking_comms(blocking)
            .with_warmup(true),
    )
    .unwrap();
    let n = 24;
    let t0 = Instant::now();
    let rrefs: Vec<_> = (0..n)
        .map(|k| {
            engine
                .infer_batch(vec![Request::new(k, vec![(k % 90) as i32 + 1; 10])])
                .unwrap()
        })
        .collect();
    for r in rrefs {
        r.to_here().unwrap();
    }
    let per = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
    println!(
        "live tiny pp=2 {}: {per:.2} ms/batch over {n} streamed batches",
        if blocking { "blocking (FT-style)" } else { "NBPP" }
    );
    engine.shutdown();
}

fn main() {
    println!("{}", report::fig11());

    println!("live grounding (real PJRT execution, tiny preset):");
    live_pp(false);
    live_pp(true);
}
