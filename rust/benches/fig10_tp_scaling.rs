//! Fig. 10 — tensor parallelism scalability of a 12-layer GPT-3 on the
//! fully NVLink-connected 8-GPU server, plus a live grounding run: the
//! same TP orchestration (shards + ring all-reduce + host residuals)
//! measured on real PJRT execution with the tiny preset.

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::coordinator::Request;
use energonai::sim::report;
use energonai::util::bench::run_print;

fn live_tp(tp: usize) {
    let engine = Engine::launch(
        LaunchConfig::preset("tiny").with_parallel(tp, 1).with_warmup(true),
    )
    .unwrap();
    run_print(&format!("live tiny tp={tp} batch(2,16) end-to-end"), 3, 20, || {
        let r = engine
            .infer_batch(vec![
                Request::new(0, vec![5; 12]),
                Request::new(1, vec![9; 12]),
            ])
            .unwrap();
        r.to_here().unwrap();
    });
    engine.shutdown();
}

fn main() {
    println!("{}", report::fig10());

    println!("live grounding (real PJRT execution, tiny preset, 1-core CPU —");
    println!("parallel configs time-slice one core; this measures coordination cost):");
    live_tp(1);
    live_tp(2);
}
