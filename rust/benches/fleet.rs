//! Fleet benchmark: throughput of the session-affine router at 1/2/4
//! replicas on the seeded saturation scenario, plus a kill-and-failover
//! cell — 3 replicas, one killed mid-run on the scenario's own seeded
//! schedule — measuring TTFT/TPOT through the failure and the per-
//! failover replay latency.
//!
//! Hard gates (exit 1): survivor streams through the kill must stay
//! byte-identical to a single-engine no-kill control, no session may be
//! lost (errors == 0, every turn completes), and no K/V block may leak
//! on either tier fleet-wide.
//!
//! Results land machine-readably in `BENCH_fleet.json` at the repo root
//! (regenerate with `scripts/bench_fleet.sh`; `BENCH_SMOKE=1` runs a
//! smaller client pool for CI).

use energonai::coordinator::engine::LaunchConfig;
use energonai::coordinator::fleet::Fleet;
use energonai::memory::kvcache;
use energonai::runtime::find_artifacts;
use energonai::workload::loadgen::{
    parity_mismatches, pctl_us, run_fleet_saturation, LoadReport, ReplicaKill,
    SaturationScenario,
};
use std::time::Duration;

type Results = Vec<(String, f64)>;

const SEED: u64 = 2209;

fn run_cell(
    label: &str,
    replicas: usize,
    scenario: &SaturationScenario,
    kills: &[ReplicaKill],
    results: &mut Results,
) -> Option<(LoadReport, u64)> {
    // the context cap is a property of the compiled artifacts, identical
    // across replicas
    let max_context = energonai::runtime::Manifest::cached(find_artifacts().ok()?)
        .ok()?
        .shape_points("tiny")
        .iter()
        .map(|&(_, s)| s)
        .max()?;
    let before = kvcache::global_stats();
    let fleet = match Fleet::launch(LaunchConfig::preset("tiny").with_warmup(true), replicas) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("skip {label}: {e:#}");
            return None;
        }
    };
    let report = run_fleet_saturation(&fleet, scenario, max_context, kills);
    let stats = fleet.stats();
    fleet.shutdown();
    let after = kvcache::global_stats();
    let leaked = after.blocks_in_use.saturating_sub(before.blocks_in_use)
        + after.host_bytes.saturating_sub(before.host_bytes)
        + after.double_free.saturating_sub(before.double_free);
    let failover_p50 =
        stats.failover_percentile(0.50).map_or(0, |d| d.as_micros() as u64);
    let failover_p99 =
        stats.failover_percentile(0.99).map_or(0, |d| d.as_micros() as u64);
    println!(
        "{label:>12}: {} turns in {:.1}ms — {} completed / {} shed ({} recovered) / {} errors; \
         {:.0} tok/s, TTFT p99 {}µs, TPOT p99 {}µs; {} failovers (p50 {}µs p99 {}µs), {} leaked",
        report.turns(),
        report.wall.as_secs_f64() * 1e3,
        report.completed,
        report.shed,
        report.recovered,
        report.errors,
        report.tokens_per_sec(),
        pctl_us(&report.ttft_us, 99.0),
        pctl_us(&report.tpot_us, 99.0),
        stats.failovers,
        failover_p50,
        failover_p99,
        leaked,
    );
    let key = |k: &str| format!("{label}_{k}");
    results.push((key("replicas"), replicas as f64));
    results.push((key("turns"), report.turns() as f64));
    results.push((key("completed"), report.completed as f64));
    results.push((key("shed"), report.shed as f64));
    results.push((key("recovered"), report.recovered as f64));
    results.push((key("busy_rejections"), report.busy_rejections as f64));
    results.push((key("errors"), report.errors as f64));
    results.push((key("tokens_per_sec"), report.tokens_per_sec()));
    results.push((key("wall_us"), report.wall.as_secs_f64() * 1e6));
    results.push((key("ttft_p50_us"), pctl_us(&report.ttft_us, 50.0) as f64));
    results.push((key("ttft_p99_us"), pctl_us(&report.ttft_us, 99.0) as f64));
    results.push((key("tpot_p50_us"), pctl_us(&report.tpot_us, 50.0) as f64));
    results.push((key("tpot_p99_us"), pctl_us(&report.tpot_us, 99.0) as f64));
    results.push((key("failovers"), stats.failovers as f64));
    results.push((key("failover_p50_us"), failover_p50 as f64));
    results.push((key("failover_p99_us"), failover_p99 as f64));
    results.push((key("leaked_blocks"), leaked as f64));
    Some((report, leaked))
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fleet.json");
    let mut body = String::from("{\n  \"schema\": \"bench_fleet/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_fleet.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str(&format!("  \"seed\": {SEED},\n"));
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (clients, turns) = if smoke { (8, 3) } else { (16, 4) };
    let scenario = SaturationScenario::new(SEED, clients, turns);

    println!("== fleet: {clients} clients x {turns} turns, seed {SEED} ==\n");
    let mut results = Results::new();
    results.push(("clients".into(), clients as f64));
    results.push(("turns_per_client".into(), turns as f64));

    // throughput scaling: the same traffic over 1/2/4 replicas
    let control = run_cell("n1", 1, &scenario, &[], &mut results);
    run_cell("n2", 2, &scenario, &[], &mut results);
    run_cell("n4", 4, &scenario, &[], &mut results);

    // kill-and-failover: 3 replicas, one killed mid-run on the seeded
    // schedule; latency percentiles include streams that failed over
    let kills = scenario.kill_schedule(3, 1, Duration::from_millis(60));
    let killed = run_cell("kill1of3", 3, &scenario, &kills, &mut results);

    if let (Some((control, leak_c)), Some((killed, leak_k))) = (control, killed) {
        let diffs = parity_mismatches(&control, &killed);
        results.push(("parity".into(), if diffs.is_empty() { 1.0 } else { 0.0 }));
        println!(
            "\nparity: {}",
            if diffs.is_empty() {
                "streams through the kill byte-identical to the 1-replica control".to_string()
            } else {
                format!("DIVERGED:\n{}", diffs.join("\n"))
            }
        );
        let lost = killed.turns() - killed.completed - killed.shed;
        let leaked = leak_c + leak_k;
        write_json(&results);
        if !diffs.is_empty() || lost > 0 || leaked > 0 {
            // the counters on disk are the evidence; fail the smoke gate
            eprintln!("FAIL: parity_diffs={} lost_sessions={lost} leaked={leaked}", diffs.len());
            std::process::exit(1);
        }
        return;
    }
    write_json(&results);
}
