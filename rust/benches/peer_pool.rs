//! Three-tier KV cache benchmark: the peer (park) tier and the
//! overlapped copier vs. inline copies and host-only spill (the ISSUE 10
//! acceptance experiment).
//!
//! The claims under test: (1) a workload that overflows the device tier
//! completes with byte-identical token streams whether the overflow
//! parks in a ring peer's memory, spills to host, or stays resident;
//! (2) with the copier thread landing staged images behind the current
//! forward, `prefetch_stall_us` falls materially below the inline-copy
//! baseline of the same three-tier config; (3) no tier leaks a block.
//!
//! Results land machine-readably in `BENCH_peer.json` at the repo root
//! (regenerate with `scripts/bench_peer.sh`; `BENCH_SMOKE=1` runs a
//! smaller session wave for CI).

use energonai::coordinator::engine::{Engine, GenRef, GenRequest, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::find_artifacts;
use std::time::Instant;

type Results = Vec<(String, f64)>;

struct CellOut {
    tokens: Vec<Vec<i32>>,
    stall_us: f64,
    leaked: bool,
}

#[derive(Clone, Copy)]
enum Cell {
    Resident,
    HostOnly,
    PeerInline,
    PeerCopier,
}

impl Cell {
    fn label(self) -> &'static str {
        match self {
            Cell::Resident => "resident",
            Cell::HostOnly => "host_only",
            Cell::PeerInline => "peer_inline",
            Cell::PeerCopier => "peer_copier",
        }
    }
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// Run `sessions` concurrent generations on a fresh engine configured
/// for one grid cell; `device` blocks per worker when tiering is on.
fn run_cell(
    cell: Cell,
    sessions: usize,
    new_tokens: usize,
    device: usize,
    results: &mut Results,
) -> Option<CellOut> {
    let label = cell.label();
    let mut lc = LaunchConfig::preset("tiny").with_warmup(true);
    // identical dispatcher pool in every cell: stall deltas must measure
    // copy placement, not a different in-flight bound
    lc.engine.pool_threads = 2;
    match cell {
        Cell::Resident => {}
        Cell::HostOnly => lc = lc.with_kv_spill(device, 0),
        Cell::PeerInline => lc = lc.with_kv_spill(device, 0).with_kv_peer(device),
        Cell::PeerCopier => {
            lc = lc.with_kv_spill(device, 0).with_kv_peer(device).with_kv_copier(true)
        }
    }
    let engine = match Engine::launch(lc) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip {label}: {e:#}");
            return None;
        }
    };
    if !engine.kv_cache_on() {
        eprintln!("skip {label}: decode artifacts missing");
        engine.shutdown();
        return None;
    }
    let before = kvcache::global_stats();
    let t0 = Instant::now();
    let grefs: Vec<GenRef> = prompts(sessions)
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, new_tokens)).unwrap())
        .collect();
    let tokens: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    let wall = t0.elapsed();
    let m = engine.metrics_snapshot();
    let stats = m.kvcache_stats();
    let stall_us = (stats.prefetch_stall_us - before.prefetch_stall_us) as f64;
    println!(
        "{label:>12}: {sessions} sessions x {new_tokens} toks in {:.1}ms; \
         {} parks / {} fetches / {} demotes, {} spills / {} prefetches, stall {:.1}ms",
        wall.as_secs_f64() * 1e3,
        stats.parks - before.parks,
        stats.fetches - before.fetches,
        stats.demotes - before.demotes,
        stats.spills - before.spills,
        stats.prefetches - before.prefetches,
        stall_us / 1e3,
    );
    engine.shutdown();
    let after = kvcache::global_stats();
    let leaked = after.blocks_in_use != before.blocks_in_use
        || after.host_bytes != before.host_bytes
        || after.peer_bytes != before.peer_bytes;
    if leaked {
        eprintln!(
            "{label}: LEAK device {}->{} host {}->{} peer {}->{}",
            before.blocks_in_use,
            after.blocks_in_use,
            before.host_bytes,
            after.host_bytes,
            before.peer_bytes,
            after.peer_bytes,
        );
    }
    let key = |k: &str| format!("{label}_{k}");
    results.push((key("wall_us"), wall.as_secs_f64() * 1e6));
    results.push((key("parks"), (stats.parks - before.parks) as f64));
    results.push((key("fetches"), (stats.fetches - before.fetches) as f64));
    results.push((key("demotes"), (stats.demotes - before.demotes) as f64));
    results.push((key("spills"), (stats.spills - before.spills) as f64));
    results.push((key("prefetches"), (stats.prefetches - before.prefetches) as f64));
    results.push((key("park_bytes"), (stats.park_bytes - before.park_bytes) as f64));
    results.push((key("fetch_bytes"), (stats.fetch_bytes - before.fetch_bytes) as f64));
    results.push((key("prefetch_stall_us"), stall_us));
    results.push((key("gather_spilled"), (stats.gather_spilled - before.gather_spilled) as f64));
    results.push((key("leaked"), if leaked { 1.0 } else { 0.0 }));
    if let Some(d) = m.token_percentile(0.99) {
        results.push((key("tok_p99_us"), d.as_secs_f64() * 1e6));
    }
    Some(CellOut { tokens, stall_us, leaked })
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_peer.json");
    let mut body = String::from("{\n  \"schema\": \"bench_peer/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_peer.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // tiny sessions run to <= 16 positions => <= 2 blocks each. A device
    // tier of 8 blocks holds ~K=4 sessions; the wave is >= 3K.
    let (sessions, new_tokens, device) = if smoke { (12, 4, 8) } else { (24, 8, 8) };

    println!("== three-tier KV cache: {sessions} concurrent sessions, device tier {device} blocks ==\n");
    let mut results = Results::new();
    let cells = [Cell::Resident, Cell::HostOnly, Cell::PeerInline, Cell::PeerCopier];
    let outs: Vec<Option<CellOut>> =
        cells.iter().map(|&c| run_cell(c, sessions, new_tokens, device, &mut results)).collect();

    let mut failed = false;
    if let Some(Some(base)) = outs.first() {
        for (cell, out) in cells.iter().zip(&outs).skip(1) {
            let Some(out) = out else { continue };
            let parity = out.tokens == base.tokens;
            results.push((format!("{}_parity", cell.label()), if parity { 1.0 } else { 0.0 }));
            if !parity {
                eprintln!("{}: token streams DIVERGED from resident (tiering bug!)", cell.label());
                failed = true;
            }
            failed |= out.leaked;
        }
    }
    if let (Some(Some(inline)), Some(Some(copier))) = (outs.get(2), outs.get(3)) {
        // the acceptance claim: staged landings behind the forward beat
        // inline copies. Tiny-preset stalls are noisy; equality counts
        // only when both rounds are already sub-millisecond.
        let ratio = if inline.stall_us > 0.0 { copier.stall_us / inline.stall_us } else { 1.0 };
        results.push(("copier_stall_ratio".into(), ratio));
        println!(
            "\nprefetch stall copier/inline: {:.2}x ({:.1}ms -> {:.1}ms)",
            ratio,
            inline.stall_us / 1e3,
            copier.stall_us / 1e3
        );
        if copier.stall_us > inline.stall_us && copier.stall_us > 1_000.0 {
            eprintln!("copier REGRESSED the prefetch stall");
            failed = true;
        }
    }
    write_json(&results);
    if failed {
        std::process::exit(1);
    }
}
