//! Iteration-level scheduler benchmark: single-client vs N-client
//! coalesced decode through the continuation batcher (the ISSUE 2
//! acceptance experiment). Reports wall time, mean batch occupancy,
//! TTFT and per-token latency percentiles, and tokens/sec; medians land
//! machine-readably in `BENCH_scheduler.json` at the repo root
//! (regenerate with `scripts/bench_scheduler.sh`).

use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
use energonai::workload::GenScenario;
use std::time::Instant;

/// (metric name, value) pairs destined for BENCH_scheduler.json.
type Results = Vec<(String, f64)>;

fn fmt_us(v: Option<std::time::Duration>) -> String {
    v.map(|d| format!("{:.1}µs", d.as_secs_f64() * 1e6)).unwrap_or_else(|| "-".into())
}

/// Run one scenario on a fresh engine (fresh metrics) and report.
fn run_scenario(label: &str, clients: usize, new_tokens: usize, results: &mut Results) {
    let engine = Engine::launch(LaunchConfig::preset("tiny").with_warmup(true)).unwrap();
    let sc = GenScenario::concurrent(clients, new_tokens, 8, engine.cfg.vocab);
    let t0 = Instant::now();
    let grefs: Vec<_> = sc
        .prompts()
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, sc.new_tokens)).unwrap())
        .collect();
    let mut generated = 0usize;
    for g in &grefs {
        generated += g.to_here().unwrap().len() - g.prompt().len();
    }
    let wall = t0.elapsed();
    let m = engine.metrics_snapshot();

    println!("{label}: {clients} clients × {new_tokens} tokens");
    println!(
        "  wall {:.1}ms, {generated} tokens, {:.1} tok/s",
        wall.as_secs_f64() * 1e3,
        m.tokens_per_sec()
    );
    println!(
        "  occupancy {:.2} ({} rows / {} batches)",
        m.mean_occupancy(),
        m.requests(),
        m.batches()
    );
    println!(
        "  ttft p50 {} p95 {} p99 {}",
        fmt_us(m.ttft_percentile(0.50)),
        fmt_us(m.ttft_percentile(0.95)),
        fmt_us(m.ttft_percentile(0.99)),
    );
    println!(
        "  tok  p50 {} p95 {} p99 {}",
        fmt_us(m.token_percentile(0.50)),
        fmt_us(m.token_percentile(0.95)),
        fmt_us(m.token_percentile(0.99)),
    );
    if clients > 1 && m.mean_occupancy() <= 1.0 {
        println!("  WARN: decode steps did not coalesce (occupancy ≤ 1)");
    }

    let key = |k: &str| format!("{label}_{k}");
    results.push((key("wall_us"), wall.as_secs_f64() * 1e6));
    results.push((key("tokens"), generated as f64));
    results.push((key("tokens_per_sec"), m.tokens_per_sec()));
    results.push((key("occupancy"), m.mean_occupancy()));
    for (name, v) in [
        ("ttft_p50_us", m.ttft_percentile(0.50)),
        ("ttft_p99_us", m.ttft_percentile(0.99)),
        ("tok_p50_us", m.token_percentile(0.50)),
        ("tok_p99_us", m.token_percentile(0.99)),
    ] {
        if let Some(d) = v {
            results.push((key(name), d.as_secs_f64() * 1e6));
        }
    }
    engine.shutdown();
    println!();
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scheduler.json");
    let mut body = String::from("{\n  \"schema\": \"bench_scheduler/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_scheduler.sh\",\n");
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if energonai::runtime::find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    println!("== iteration-level scheduler: coalesced decode ==\n");
    let mut results = Results::new();
    run_scenario("single", 1, 16, &mut results);
    run_scenario("multi4", 4, 16, &mut results);
    run_scenario("multi8", 8, 16, &mut results);
    write_json(&results);
}
