//! Fig. 2 — normalized kernel execution time distribution of GPT models
//! (125M → 175B) at batch 32 / seq 64 / FP16, from the A100 roofline
//! model. The paper's headline: GEMM share grows ~62% → ~96%, which is
//! why EnergonAI stops relying on kernel fusion at scale (§3.1).

use energonai::perf::{breakdown, DeviceModel};
use energonai::sim::report;

fn main() {
    println!("{}", report::fig2());

    // machine-readable anchors for EXPERIMENTS.md
    let dists = breakdown::fig2(&DeviceModel::default());
    let small = dists.iter().find(|d| d.model == "gpt-125M").unwrap();
    let big = dists.iter().find(|d| d.model == "gpt-175B").unwrap();
    println!(
        "ANCHOR fig2 gemm_share 125M={:.1}% (paper ~62%)  175B={:.1}% (paper ~96%)",
        small.share("gemm") * 100.0,
        big.share("gemm") * 100.0
    );
}
