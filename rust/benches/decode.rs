//! Incremental-decode benchmark: per-token decode latency vs. prefix
//! length, paged KV cache vs. the legacy re-prefill path (the ISSUE 3
//! acceptance experiment).
//!
//! The claim under test: with the cache, a decode step runs O(1) positions
//! through the linears, so per-token latency stays flat as the prefix
//! grows; without it every step re-runs the whole prefix, so per-token
//! latency grows roughly linearly with prefix length.
//!
//! Medians land machine-readably in `BENCH_decode.json` at the repo root
//! (regenerate with `scripts/bench_decode.sh`; `BENCH_SMOKE=1` runs a
//! fast single-prefix sanity pass for CI).

use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
use energonai::runtime::{find_artifacts, Manifest};
use std::time::Instant;

type Results = Vec<(String, f64)>;

/// Per-token decode p50 for one (preset, prefix, cache) cell, on a fresh
/// engine so metrics are isolated.
fn run_cell(preset: &str, prefix: usize, new_tokens: usize, cache: bool, results: &mut Results) -> Option<f64> {
    let engine = match Engine::launch(
        LaunchConfig::preset(preset).with_warmup(true).with_kv_cache(cache),
    ) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip {preset} p{prefix} cache={cache}: {e:#}");
            return None;
        }
    };
    if cache && !engine.kv_cache_on() {
        eprintln!("skip {preset} p{prefix}: decode artifacts missing");
        engine.shutdown();
        return None;
    }
    let prompt: Vec<i32> = (0..prefix).map(|i| (i % 90 + 1) as i32).collect();
    let t0 = Instant::now();
    let out = engine.generate_stream(GenRequest::new(prompt, new_tokens)).unwrap();
    let full = out.to_here().unwrap();
    let wall = t0.elapsed();
    let m = engine.metrics_snapshot();
    let p50 = m.token_percentile(0.50).map(|d| d.as_secs_f64() * 1e6);
    let label = if cache { "cache" } else { "nocache" };
    println!(
        "{preset} prefix {prefix:>4} {label:>7}: {} tokens in {:.1}ms, tok p50 {}",
        full.len() - prefix,
        wall.as_secs_f64() * 1e3,
        p50.map(|v| format!("{v:.1}µs")).unwrap_or_else(|| "-".into()),
    );
    let key = |k: &str| format!("{label}_prefix{prefix}_{k}");
    results.push((key("wall_us"), wall.as_secs_f64() * 1e6));
    if let Some(v) = p50 {
        results.push((key("tok_p50_us"), v));
    }
    if let Some(d) = m.token_percentile(0.99) {
        results.push((key("tok_p99_us"), d.as_secs_f64() * 1e6));
    }
    engine.shutdown();
    p50
}

fn write_json(preset: &str, results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    let mut body = String::from("{\n  \"schema\": \"bench_decode/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_decode.sh\",\n");
    body.push_str(&format!("  \"preset\": \"{preset}\",\n"));
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let dir = match find_artifacts() {
        Ok(d) => d,
        Err(_) => {
            eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
            return;
        }
    };
    let manifest = Manifest::cached(dir).unwrap();
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // the base preset carries the (1, 128) long-context point for the
    // sweep; fall back to tiny (max prefix 24) when it isn't compiled
    let (preset, prefixes, new_tokens) = if smoke {
        ("tiny", vec![8], 4)
    } else if !manifest.decode_widths("base", 1).is_empty() {
        ("base", vec![8, 32, 120], 8)
    } else {
        eprintln!("(base decode artifacts missing — falling back to the tiny sweep)");
        ("tiny", vec![8, 16, 24], 8)
    };

    println!("== incremental decode: per-token latency vs prefix ({preset}) ==\n");
    let mut results = Results::new();
    let mut flat: Vec<(usize, f64, f64)> = Vec::new(); // (prefix, cache, nocache)
    for &p in &prefixes {
        let c = run_cell(preset, p, new_tokens, true, &mut results);
        let n = run_cell(preset, p, new_tokens, false, &mut results);
        if let (Some(c), Some(n)) = (c, n) {
            flat.push((p, c, n));
        }
        println!();
    }
    if let (Some(first), Some(last)) = (flat.first(), flat.last()) {
        if flat.len() >= 2 {
            println!(
                "cache p50 growth {}→{}: {:.2}x (acceptance: ≤1.2x); \
                 nocache growth: {:.2}x",
                first.0,
                last.0,
                last.1 / first.1,
                last.2 / first.2,
            );
        }
    }
    write_json(preset, &results);
}
