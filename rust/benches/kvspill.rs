//! Tiered-KV-cache benchmark: concurrent sessions served vs. device slab
//! size, spill on vs. off (the ISSUE 4 acceptance experiment).
//!
//! The claim under test: with the host tier enabled, a device slab sized
//! for K sessions serves 3K+ concurrent generation sessions with
//! byte-identical token streams and bounded decode-latency degradation
//! (< 2× the resident-only p99), because cold sessions' blocks park in
//! pooled host memory between decode steps and are prefetched back one
//! bucket ahead of re-entry.
//!
//! Results land machine-readably in `BENCH_kvspill.json` at the repo root
//! (regenerate with `scripts/bench_kvspill.sh`; `BENCH_SMOKE=1` runs a
//! smaller session wave for CI).

use energonai::coordinator::engine::{Engine, GenRequest, GenRef, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::find_artifacts;
use std::time::Instant;

type Results = Vec<(String, f64)>;

struct CellOut {
    tokens: Vec<Vec<i32>>,
    p99_us: Option<f64>,
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 3) % 7;
            (0..len).map(|j| ((i * 31 + j * 7) % 100 + 1) as i32).collect()
        })
        .collect()
}

/// Run `sessions` concurrent generations on a fresh engine; `device`
/// blocks per worker when spilling (0 = resident-only baseline).
fn run_cell(sessions: usize, new_tokens: usize, device: usize, results: &mut Results) -> Option<CellOut> {
    let label = if device > 0 { "spill" } else { "resident" };
    let mut lc = LaunchConfig::preset("tiny").with_warmup(true);
    // identical dispatcher pool in both cells: the p99 ratio must
    // measure tiering overhead, not a different in-flight bound
    lc.engine.pool_threads = 2;
    if device > 0 {
        lc = lc.with_kv_spill(device, 0);
    }
    let engine = match Engine::launch(lc) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip {label}: {e:#}");
            return None;
        }
    };
    if !engine.kv_cache_on() {
        eprintln!("skip {label}: decode artifacts missing");
        engine.shutdown();
        return None;
    }
    let before = kvcache::global_stats();
    let t0 = Instant::now();
    let grefs: Vec<GenRef> = prompts(sessions)
        .into_iter()
        .map(|p| engine.generate_stream(GenRequest::new(p, new_tokens)).unwrap())
        .collect();
    let tokens: Vec<Vec<i32>> = grefs.iter().map(|g| g.to_here().unwrap()).collect();
    let wall = t0.elapsed();
    let m = engine.metrics_snapshot();
    let stats = m.kvcache_stats();
    let p99 = m.token_percentile(0.99).map(|d| d.as_secs_f64() * 1e6);
    println!(
        "{label:>8}: {sessions} sessions x {new_tokens} toks in {:.1}ms; tok p99 {}; \
         {} spills / {} prefetches ({} out, {} in), stall {:.1}ms, peak {} blocks",
        wall.as_secs_f64() * 1e3,
        p99.map(|v| format!("{v:.1}µs")).unwrap_or_else(|| "-".into()),
        stats.spills - before.spills,
        stats.prefetches - before.prefetches,
        stats.spill_bytes - before.spill_bytes,
        stats.prefetch_bytes - before.prefetch_bytes,
        (stats.prefetch_stall_us - before.prefetch_stall_us) as f64 / 1e3,
        stats.blocks_peak,
    );
    let key = |k: &str| format!("{label}_{k}");
    results.push((key("wall_us"), wall.as_secs_f64() * 1e6));
    results.push((key("sessions"), sessions as f64));
    results.push((key("spills"), (stats.spills - before.spills) as f64));
    results.push((key("prefetches"), (stats.prefetches - before.prefetches) as f64));
    results.push((key("spill_bytes"), (stats.spill_bytes - before.spill_bytes) as f64));
    results.push((key("prefetch_bytes"), (stats.prefetch_bytes - before.prefetch_bytes) as f64));
    results.push((
        key("prefetch_stall_us"),
        (stats.prefetch_stall_us - before.prefetch_stall_us) as f64,
    ));
    results.push((key("gather_spilled"), (stats.gather_spilled - before.gather_spilled) as f64));
    results.push((key("overflow_blocks"), (stats.overflow_blocks - before.overflow_blocks) as f64));
    if let Some(v) = p99 {
        results.push((key("tok_p99_us"), v));
    }
    if let Some(d) = m.token_percentile(0.50) {
        results.push((key("tok_p50_us"), d.as_secs_f64() * 1e6));
    }
    engine.shutdown();
    Some(CellOut { tokens, p99_us: p99 })
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kvspill.json");
    let mut body = String::from("{\n  \"schema\": \"bench_kvspill/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_kvspill.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // tiny sessions run to <= 16 positions => <= 2 blocks each. A device
    // tier of 8 blocks holds ~K=4 sessions; the wave is >= 3K.
    let (sessions, new_tokens, device) = if smoke { (12, 4, 8) } else { (24, 8, 8) };

    println!("== tiered KV cache: {sessions} concurrent sessions, device tier {device} blocks ==\n");
    let mut results = Results::new();
    let resident = run_cell(sessions, new_tokens, 0, &mut results);
    let spilled = run_cell(sessions, new_tokens, device, &mut results);
    if let (Some(r), Some(s)) = (resident, spilled) {
        let parity = r.tokens == s.tokens;
        results.push(("parity".into(), if parity { 1.0 } else { 0.0 }));
        println!(
            "\nparity: {}",
            if parity { "byte-identical token streams" } else { "DIVERGED (tiering bug!)" }
        );
        if let (Some(rp), Some(sp)) = (r.p99_us, s.p99_us) {
            results.push(("p99_ratio".into(), sp / rp));
            println!(
                "tok p99 spill/resident: {:.2}x (acceptance: < 2x)",
                sp / rp
            );
        }
        if !parity {
            // keep the counters on disk — they are the evidence needed
            // to debug the divergence — then fail the smoke gate
            write_json(&results);
            std::process::exit(1);
        }
    }
    write_json(&results);
}
