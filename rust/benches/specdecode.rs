//! Speculative-decode benchmark: per-token latency, accept rate and
//! tokens-per-pass, plain decode vs draft-and-verify at k∈{2,4} (the
//! ISSUE 5 acceptance experiment).
//!
//! Two workloads bound the accept-rate sweep: a *repetitive* prompt
//! (cyclic tokens — the regime n-gram drafting, and small greedy models,
//! both love) and an *adversarial* pseudo-random prompt. Each speculative
//! cell runs twice, once with the free n-gram drafter and once with a
//! replay drafter fed the known greedy continuation — the perfect
//! small-model stand-in that shows the ceiling. Every cell's stream is
//! compared byte-for-byte against the plain run; a mismatch exits
//! non-zero (speculation must be lossless), which is what the CI smoke
//! leg gates on.
//!
//! Medians land machine-readably in `BENCH_specdecode.json` at the repo
//! root (regenerate with `scripts/bench_specdecode.sh`; `BENCH_SMOKE=1`
//! runs a fast single-workload pass for CI).

use energonai::coordinator::drafter::{NGramDrafter, ReplayDrafter};
use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
use energonai::runtime::{find_artifacts, Manifest};
use std::time::Instant;

type Results = Vec<(String, f64)>;

struct Cell {
    stream: Vec<i32>,
    wall_us: f64,
    tok_p50_us: Option<f64>,
    tokens_per_pass: Option<f64>,
    accept_rate: Option<f64>,
}

fn run_cell(
    prompt: &[i32],
    new_tokens: usize,
    spec_k: usize,           // 0 = plain decode
    replay: Option<&[i32]>,  // Some(truth) = perfect drafter
) -> Cell {
    let mut lc = LaunchConfig::preset("tiny").with_warmup(true);
    if spec_k > 0 {
        lc = lc.with_speculative(true).with_spec_k(spec_k);
        if let Some(truth) = replay {
            lc = lc.with_drafter(ReplayDrafter { script: truth.to_vec() });
        } else {
            lc = lc.with_drafter(NGramDrafter::default());
        }
    }
    let engine = Engine::launch(lc).expect("engine launch");
    if spec_k > 0 {
        assert!(engine.speculative_on(), "verify artifacts missing — run `make artifacts`");
    }
    let t0 = Instant::now();
    let stream = engine
        .generate_stream(GenRequest::new(prompt.to_vec(), new_tokens))
        .unwrap()
        .to_here()
        .unwrap();
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    let m = engine.metrics_snapshot();
    let cell = Cell {
        stream,
        wall_us,
        tok_p50_us: m.token_percentile(0.50).map(|d| d.as_secs_f64() * 1e6),
        tokens_per_pass: m.spec_tokens_per_pass(),
        accept_rate: m.spec_accept_rate(),
    };
    engine.shutdown();
    cell
}

fn push(results: &mut Results, key: String, v: Option<f64>) {
    if let Some(v) = v {
        results.push((key, v));
    }
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_specdecode.json");
    let mut body = String::from("{\n  \"schema\": \"bench_specdecode/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_specdecode.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.3}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts()
        .ok()
        .and_then(|d| Manifest::cached(d).ok())
        .map(|m| m.verify_points("tiny", 1).is_empty())
        .unwrap_or(true)
    {
        eprintln!("no verify artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let new_tokens = if smoke { 8 } else { 16 };
    let ks: &[usize] = if smoke { &[4] } else { &[2, 4] };
    // the accept-rate sweep's two poles
    let repetitive: Vec<i32> = [7, 8, 9].iter().cycle().take(12).copied().collect();
    let adversarial: Vec<i32> = (0..12).map(|i| (i * 37 + 11) % 90 + 1).collect();
    let workloads: Vec<(&str, Vec<i32>)> = if smoke {
        vec![("repetitive", repetitive)]
    } else {
        vec![("repetitive", repetitive), ("adversarial", adversarial)]
    };

    println!("== speculative decode: accept rate & tokens-per-pass (tiny) ==\n");
    let mut results = Results::new();
    let mut parity_ok = true;
    for (wname, prompt) in &workloads {
        let plain = run_cell(prompt, new_tokens, 0, None);
        println!(
            "{wname:>11} plain   : {} toks in {:.1}ms, tok p50 {}",
            plain.stream.len() - prompt.len(),
            plain.wall_us / 1e3,
            plain.tok_p50_us.map(|v| format!("{v:.0}µs")).unwrap_or_else(|| "-".into()),
        );
        results.push((format!("plain_{wname}_wall_us"), plain.wall_us));
        push(&mut results, format!("plain_{wname}_tok_p50_us"), plain.tok_p50_us);
        for &k in ks {
            for (dname, replay) in
                [("ngram", None), ("replay", Some(plain.stream.as_slice()))]
            {
                let c = run_cell(prompt, new_tokens, k, replay);
                let ok = c.stream == plain.stream;
                parity_ok &= ok;
                println!(
                    "{wname:>11} k{k} {dname:>6}: tok/pass {} accept {} tok p50 {}{}",
                    c.tokens_per_pass.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                    c.accept_rate.map(|v| format!("{:.0}%", v * 100.0)).unwrap_or_else(|| "-".into()),
                    c.tok_p50_us.map(|v| format!("{v:.0}µs")).unwrap_or_else(|| "-".into()),
                    if ok { "" } else { "  PARITY FAILURE" },
                );
                let key = |s: &str| format!("spec_k{k}_{wname}_{dname}_{s}");
                results.push((key("wall_us"), c.wall_us));
                push(&mut results, key("tok_p50_us"), c.tok_p50_us);
                push(&mut results, key("tokens_per_pass"), c.tokens_per_pass);
                push(&mut results, key("accept_rate"), c.accept_rate);
                results.push((key("parity"), if ok { 1.0 } else { 0.0 }));
            }
        }
        println!();
    }
    write_json(&results);
    // acceptance: tokens-per-pass > 1.3 on the repetitive workload with a
    // good drafter (the replay ceiling pins it deterministically)
    let tpp = results
        .iter()
        .find(|(k, _)| k.ends_with("repetitive_replay_tokens_per_pass"))
        .map(|&(_, v)| v)
        .unwrap_or(0.0);
    println!("repetitive tokens-per-pass (replay ceiling): {tpp:.2} (acceptance: > 1.3)");
    if !parity_ok {
        eprintln!("FAILED: a speculative stream diverged from plain decode");
        std::process::exit(1);
    }
    if tpp <= 1.3 {
        eprintln!("FAILED: tokens-per-pass {tpp:.2} <= 1.3 on the repetitive workload");
        std::process::exit(1);
    }
}
