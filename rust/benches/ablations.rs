//! Ablations over DESIGN.md's called-out design choices:
//!
//!  A. distributed consistency queue ON vs OFF — the §4.2 hazard: with the
//!     queue off, racing engine dispatchers can make TP workers pair
//!     mismatched batches in the all-reduce (wrong results).
//!  B. PMEP prefetch lookahead sweep (sim, paper-scale).
//!  C. blocking vs non-blocking collectives at a fixed topology (sim).
//!  D. batcher bucket granularity — padding waste vs compiled-shape count.

use energonai::comm::topology::Topology;
use energonai::config::ModelConfig;
use energonai::coordinator::batcher::{Batcher, Request};
use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::perf::DeviceModel;
use energonai::sim::{pipeline, pmep, System};
use energonai::tensor::Tensor;
use energonai::workload::{Generator, LengthDist};
use std::time::Duration;

/// A: hazard rate with the consistency queue disabled.
fn ablation_consistency() {
    println!("== A. distributed consistency queue (tp=2, racing dispatchers) ==");
    // oracle: serial engine, one batch signature per k
    let make_reqs = |k: u64| vec![Request::new(k, vec![((k % 90) + 1) as i32; 8])];
    let oracle_engine = Engine::launch(LaunchConfig::preset("tiny").with_warmup(true)).unwrap();
    let oracles: Vec<Tensor> = (0..8u64)
        .map(|k| oracle_engine.infer_batch(make_reqs(k)).unwrap().to_here().unwrap().logits)
        .collect();
    oracle_engine.shutdown();

    for consistency in [true, false] {
        let mut wrong = 0usize;
        let mut total = 0usize;
        for _round in 0..6 {
            let engine = std::sync::Arc::new(
                Engine::launch(
                    LaunchConfig::preset("tiny")
                        .with_parallel(2, 1)
                        .with_consistency(consistency)
                        .with_warmup(true),
                )
                .unwrap(),
            );
            // racing submitters: two threads interleave publishes
            let handles: Vec<_> = (0..2)
                .map(|t| {
                    let engine = engine.clone();
                    std::thread::spawn(move || {
                        let mut out = Vec::new();
                        for i in 0..4u64 {
                            let k = t * 4 + i;
                            out.push((k, engine.infer_batch(make_reqs(k)).unwrap()));
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                for (k, rref) in h.join().unwrap() {
                    total += 1;
                    match rref.to_here() {
                        Ok(out) => {
                            if out.logits.max_abs_diff(&oracles[k as usize]) > 1e-3 {
                                wrong += 1;
                            }
                        }
                        Err(_) => wrong += 1,
                    }
                }
            }
            match std::sync::Arc::try_unwrap(engine) {
                Ok(e) => e.shutdown(),
                Err(_) => {}
            }
        }
        println!(
            "  consistency_queue={consistency:<5}  wrong results: {wrong}/{total}{}",
            if consistency { "  (must be 0)" } else { "  (hazard window — any >0 shows the §4.2 bug class)" }
        );
    }
    println!();
}

/// B: prefetch lookahead sweep at paper scale.
fn ablation_lookahead() {
    println!("== B. PMEP prefetch lookahead (40-layer GPT-3, 20 resident, bs=32 pad=64) ==");
    let dev = DeviceModel::default();
    let cfg = ModelConfig::preset("gpt3").unwrap().with_layers(40);
    for lookahead in [0usize, 1, 2, 4] {
        let mut q = pmep::PmepQuery::pmep(cfg.clone(), 20, 32, 64);
        q.lookahead = lookahead;
        let r = pmep::run(&q, &dev);
        println!(
            "  lookahead={lookahead}: {:.1} TFLOPS, stall {:.1}% of runtime",
            r.tflops,
            r.stall_seconds / r.total_seconds * 100.0
        );
    }
    println!();
}

/// C: blocking vs non-blocking hand-offs with everything else fixed —
/// same kernels, same topology; only the channel semantics flip.
fn ablation_blocking() {
    println!("== C. blocking vs non-blocking hand-offs, same kernels/topology (12-layer GPT-3, pp=4) ==");
    for bs in [1usize, 8, 32] {
        let q = |blocking| pipeline::PipelineQuery {
            cfg: ModelConfig::preset("gpt3").unwrap().with_layers(12),
            topo: Topology::paired_nvlink(4),
            pp: 4,
            batch: bs,
            seq: 64,
            n_batches: 32,
            system: System::EnergonAi,
            blocking_override: Some(blocking),
        };
        let nb = pipeline::makespan(&q(false));
        let bl = pipeline::makespan(&q(true));
        println!(
            "  bs={bs:<3} non-blocking {nb:.2}s vs blocking {bl:.2}s  (+{:.1}% makespan from blocking alone)",
            (bl / nb - 1.0) * 100.0
        );
    }
    println!();
}

/// D: bucket granularity vs padding waste.
fn ablation_buckets() {
    println!("== D. batcher bucket granularity (heavy-tailed lengths, max 32) ==");
    // same max batch everywhere; the sets differ in sequence-length
    // granularity, so a batch of short requests can land in a short bucket
    let bucket_sets: Vec<(&str, Vec<(usize, usize)>)> = vec![
        ("coarse [ (4,32) ]", vec![(4, 32)]),
        ("medium [ (4,16) (4,32) ]", vec![(4, 16), (4, 32)]),
        ("fine   [ (4,8) (4,16) (4,24) (4,32) ]", vec![(4, 8), (4, 16), (4, 24), (4, 32)]),
    ];
    for (label, buckets) in bucket_sets {
        let mut gen = Generator::new(11, LengthDist::HeavyTail(32, 1.1), 100);
        let mut b = Batcher::new(buckets, 4, Duration::from_micros(1));
        let mut padded_cells = 0usize;
        let mut valid_cells = 0usize;
        for _ in 0..400 {
            b.push(gen.request()).unwrap();
        }
        for fb in b.flush() {
            let (bb, ss) = fb.bucket;
            padded_cells += bb * ss;
            valid_cells += fb.requests.iter().map(|r| r.len()).sum::<usize>();
        }
        println!(
            "  {label:<34} padding waste {:.1}%",
            (1.0 - valid_cells as f64 / padded_cells as f64) * 100.0
        );
    }
    println!();
}

fn main() {
    ablation_consistency();
    ablation_lookahead();
    ablation_blocking();
    ablation_buckets();
}
