//! Chunked-prefill benchmark: a mixed long/short-prompt workload (the
//! heavy-tail `long_prompt_pct` knob) against the same engine with
//! chunking off (monolithic prefills) vs on (fixed chunk waves
//! interleaved with decode buckets).
//!
//! The claims under test: interleaving bounds the TPOT spikes decodes
//! suffer behind long prefills (max / p99 inter-token gap improves, the
//! dispatcher's `decode_stall` attribution drops), completed streams are
//! byte-identical between the two cells (hard gate — chunking must be
//! invisible in the bytes), and no K/V block leaks in either cell (hard
//! gate). A chunked max-TPOT materially above the monolithic cell's is a
//! regression and also fails the run.
//!
//! Results land machine-readably in `BENCH_chunked.json` at the repo
//! root (regenerate with `scripts/bench_chunked.sh`; `BENCH_SMOKE=1`
//! runs a smaller client pool for CI).

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::find_artifacts;
use energonai::workload::loadgen::{
    parity_mismatches, pctl_us, run_saturation, LoadReport, SaturationScenario,
};
use energonai::workload::LengthDist;

type Results = Vec<(String, f64)>;

const SEED: u64 = 2209;
/// Chunk window over the tiny preset's compiled verify ks {2, 4}.
const CHUNK: usize = 4;
/// Extra tail tokens a long prompt grows (8 + 20 stays inside the tiny
/// preset's widest monolithic prefill bucket, seq 32 — the control cell
/// must be able to serve the same prompts).
const LONG_TAIL: usize = 20;
/// Chunked max-TPOT above this multiple of the monolithic cell's is a
/// regression (tolerance absorbs scheduler noise on loaded CI hosts).
const TPOT_MAX_TOLERANCE: f64 = 1.25;
/// Minimum inter-token samples per cell before the max-TPOT gate votes.
const MIN_TPOT_SAMPLES: usize = 50;

/// Per-cell outcome the cross-cell gates need.
struct Cell {
    report: LoadReport,
    leaked: u64,
    tpot_max_us: u64,
}

fn run_cell(
    label: &str,
    lc: LaunchConfig,
    scenario: &SaturationScenario,
    results: &mut Results,
) -> Option<Cell> {
    let before = kvcache::global_stats();
    let engine = match Engine::launch(lc) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip {label}: {e:#}");
            return None;
        }
    };
    if !engine.kv_cache_on() {
        eprintln!("skip {label}: decode artifacts missing");
        engine.shutdown();
        return None;
    }
    let max_context =
        engine.manifest.shape_points("tiny").iter().map(|&(_, s)| s).max().unwrap();
    let report = run_saturation(&engine, scenario, max_context);
    let m = engine.metrics_snapshot();
    let prefill_toks = m.prefill_tokens();
    let stall_us = m.decode_stall().as_micros() as u64;
    engine.shutdown();
    let after = kvcache::global_stats();
    let leaked = after.blocks_in_use.saturating_sub(before.blocks_in_use)
        + after.host_bytes.saturating_sub(before.host_bytes)
        + after.double_free.saturating_sub(before.double_free);
    let tpot_max_us = pctl_us(&report.tpot_us, 100.0);
    println!(
        "{label:>5}: {} turns in {:.1}ms — {} completed / {} errors; {:.0} tok/s; \
         TTFT p50 {}µs p99 {}µs max {}µs; TPOT p50 {}µs p99 {}µs max {}µs; \
         {} prefill toks, decode stall {}µs, {} leaked",
        report.turns(),
        report.wall.as_secs_f64() * 1e3,
        report.completed,
        report.errors,
        report.tokens_per_sec(),
        pctl_us(&report.ttft_us, 50.0),
        pctl_us(&report.ttft_us, 99.0),
        pctl_us(&report.ttft_us, 100.0),
        pctl_us(&report.tpot_us, 50.0),
        pctl_us(&report.tpot_us, 99.0),
        tpot_max_us,
        prefill_toks,
        stall_us,
        leaked,
    );
    let key = |k: &str| format!("{label}_{k}");
    results.push((key("turns"), report.turns() as f64));
    results.push((key("completed"), report.completed as f64));
    results.push((key("errors"), report.errors as f64));
    results.push((key("tokens_per_sec"), report.tokens_per_sec()));
    results.push((key("wall_us"), report.wall.as_secs_f64() * 1e6));
    results.push((key("ttft_p50_us"), pctl_us(&report.ttft_us, 50.0) as f64));
    results.push((key("ttft_p99_us"), pctl_us(&report.ttft_us, 99.0) as f64));
    results.push((key("ttft_max_us"), pctl_us(&report.ttft_us, 100.0) as f64));
    results.push((key("tpot_p50_us"), pctl_us(&report.tpot_us, 50.0) as f64));
    results.push((key("tpot_p99_us"), pctl_us(&report.tpot_us, 99.0) as f64));
    results.push((key("tpot_max_us"), tpot_max_us as f64));
    results.push((key("tpot_samples"), report.tpot_us.len() as f64));
    results.push((key("prefill_tokens"), prefill_toks as f64));
    results.push((key("decode_stall_us"), stall_us as f64));
    results.push((key("leaked_blocks"), leaked as f64));
    Some(Cell { report, leaked, tpot_max_us })
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_chunked.json");
    let mut body = String::from("{\n  \"schema\": \"bench_chunked/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_chunked.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str(&format!("  \"seed\": {SEED},\n"));
    body.push_str(&format!("  \"chunk\": {CHUNK},\n"));
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (clients, turns) = if smoke { (8, 2) } else { (16, 4) };

    // mixed traffic: ~35% of fresh prompts grow a 20-token tail, the
    // rest stay short — long monolithic prefills collide with the short
    // sessions' decode steps, which is exactly the TPOT spike chunking
    // exists to bound
    let mut scenario =
        SaturationScenario::new(SEED, clients, turns).with_long_prompts(0.35, LONG_TAIL);
    scenario.prompt_dist = LengthDist::HeavyTail(8, 1.1);

    println!(
        "== chunked prefill: {clients} clients x {turns} turns, 35% long (+{LONG_TAIL} toks), \
         chunk {CHUNK}, seed {SEED} ==\n"
    );
    let mut results = Results::new();
    results.push(("clients".into(), clients as f64));
    results.push(("turns_per_client".into(), turns as f64));
    results.push(("long_prompt_pct".into(), 0.35));
    results.push(("long_prompt_tokens".into(), LONG_TAIL as f64));
    results.push(("chunk".into(), CHUNK as f64));

    let mono = run_cell(
        "mono",
        LaunchConfig::preset("tiny").with_warmup(true),
        &scenario,
        &mut results,
    );
    let chunk = run_cell(
        "chunk",
        LaunchConfig::preset("tiny").with_warmup(true).with_prefill_chunk(CHUNK, 1),
        &scenario,
        &mut results,
    );

    if let (Some(mono), Some(chunk)) = (mono, chunk) {
        let diffs = parity_mismatches(&mono.report, &chunk.report);
        results.push(("parity".into(), if diffs.is_empty() { 1.0 } else { 0.0 }));
        let ratio = if chunk.tpot_max_us > 0 {
            mono.tpot_max_us as f64 / chunk.tpot_max_us as f64
        } else {
            0.0
        };
        results.push(("tpot_max_improvement_x".into(), ratio));
        println!(
            "\nparity: {}",
            if diffs.is_empty() {
                "completed streams byte-identical across mono/chunk".to_string()
            } else {
                format!("DIVERGED:\n{}", diffs.join("\n"))
            }
        );
        println!(
            "max TPOT: {}µs mono vs {}µs chunked ({ratio:.2}x)",
            mono.tpot_max_us, chunk.tpot_max_us
        );
        // the max-TPOT gate only votes with a meaningful sample in both
        // cells — a near-empty smoke run must not flake CI on one gap
        let enough = mono.report.tpot_us.len() >= MIN_TPOT_SAMPLES
            && chunk.report.tpot_us.len() >= MIN_TPOT_SAMPLES;
        let regressed = enough
            && chunk.tpot_max_us as f64 > mono.tpot_max_us as f64 * TPOT_MAX_TOLERANCE;
        let leaked = mono.leaked + chunk.leaked;
        write_json(&results);
        if !diffs.is_empty() || leaked > 0 || regressed {
            // the counters on disk are the evidence; fail the smoke gate
            eprintln!(
                "FAIL: parity_diffs={} leaked={leaked} tpot_max_regressed={regressed}",
                diffs.len()
            );
            std::process::exit(1);
        }
        return;
    }
    write_json(&results);
}
