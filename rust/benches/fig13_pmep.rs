//! Fig. 13 — peer memory pooling (PMEP) vs BMInf-style CPU offload:
//! throughput in TFLOPS for 20/24/30/40-layer GPT-3 with 20 layers
//! resident, plus a live grounding run on the real engine where the copy
//! link is scaled so overlap behaviour is visible on the tiny preset.

use energonai::coordinator::engine::{Engine, LaunchConfig, MemoryMode};
use energonai::coordinator::Request;
use energonai::memory::pool::PoolConfig;
use energonai::sim::report;
use energonai::util::bench::run_print;

fn live(mode: MemoryMode, label: &str) {
    let engine = Engine::launch(
        LaunchConfig::preset("tiny").with_memory(mode).with_warmup(true),
    )
    .unwrap();
    run_print(label, 2, 12, || {
        let r = engine
            .infer_batch(vec![Request::new(0, vec![3; 10])])
            .unwrap();
        r.to_here().unwrap();
    });
    engine.shutdown();
}

fn main() {
    println!("{}", report::fig13());

    println!("live grounding (tiny preset, copy delay scaled 2000x so the link matters):");
    live(MemoryMode::Resident, "live resident (4/4 layers local)");
    let mut pmep = PoolConfig::pmep();
    pmep.time_scale = 2_000.0;
    live(
        MemoryMode::Pmep { n_local: 2, pool: pmep },
        "live pmep    (2/4 local, prefetch)",
    );
    let mut bminf = PoolConfig::bminf();
    bminf.time_scale = 2_000.0;
    live(
        MemoryMode::Pmep { n_local: 2, pool: bminf },
        "live bminf   (2/4 local, sync host)",
    );
}
