//! Fig. 12 — DRCE (distributed redundant computation elimination) vs pure
//! EnergonAI vs FasterTransformer under tensor parallelism, at the paper's
//! setup (valid length = padding/2), plus a live grounding run: the packed
//! vs padded execution path measured on real PJRT execution.

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::coordinator::Request;
use energonai::sim::report;
use energonai::util::bench::run_print;

fn live_drce(drce: bool, tp: usize) {
    let engine = Engine::launch(
        LaunchConfig::preset("tiny")
            .with_parallel(tp, 1)
            .with_drce(drce)
            .with_warmup(true),
    )
    .unwrap();
    // paper setup: valid = padding/2; (2,16) bucket with len-8 requests
    run_print(
        &format!("live tiny tp={tp} drce={drce} half-padding batch"),
        3,
        20,
        || {
            let r = engine
                .infer_batch(vec![
                    Request::new(0, vec![4; 8]),
                    Request::new(1, vec![6; 8]),
                ])
                .unwrap();
            r.to_here().unwrap();
        },
    );
    engine.shutdown();
}

fn main() {
    println!("{}", report::fig12());

    println!("live grounding (real PJRT execution; rows halve 32→16 in the linears):");
    live_drce(false, 1);
    live_drce(true, 1);
    live_drce(false, 2);
    live_drce(true, 2);
}
