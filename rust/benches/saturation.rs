//! Saturation benchmark: the seeded hostile-traffic scenario (Poisson
//! bursts, heavy-tailed lengths, multi-turn re-entry, 25% mid-stream
//! disconnects, one injected worker stall) against an engine with
//! admission control — versus an unfaulted control run on the same seed.
//!
//! The claims under test: the engine sheds instead of queueing
//! unboundedly, no K/V block leaks on either tier (hard gate), survivor
//! streams stay byte-identical to the control run (hard gate), and
//! shutdown drains cleanly under chaos.
//!
//! Results land machine-readably in `BENCH_saturation.json` at the repo
//! root (regenerate with `scripts/bench_saturation.sh`; `BENCH_SMOKE=1`
//! runs a smaller client pool for CI).

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::find_artifacts;
use energonai::workload::loadgen::{
    parity_mismatches, pctl_us, run_saturation, LoadReport, SaturationScenario,
};

type Results = Vec<(String, f64)>;

const SEED: u64 = 2209;

fn run_cell(
    label: &str,
    lc: LaunchConfig,
    scenario: &SaturationScenario,
    results: &mut Results,
) -> Option<(LoadReport, u64)> {
    let before = kvcache::global_stats();
    let engine = match Engine::launch(lc) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip {label}: {e:#}");
            return None;
        }
    };
    if !engine.kv_cache_on() {
        eprintln!("skip {label}: decode artifacts missing");
        engine.shutdown();
        return None;
    }
    let max_context =
        engine.manifest.shape_points("tiny").iter().map(|&(_, s)| s).max().unwrap();
    let report = run_saturation(&engine, scenario, max_context);
    let m = engine.metrics_snapshot();
    let (shed, cancelled) = (m.shed(), m.cancelled());
    engine.shutdown();
    let after = kvcache::global_stats();
    let leaked = after.blocks_in_use.saturating_sub(before.blocks_in_use)
        + after.host_bytes.saturating_sub(before.host_bytes)
        + after.double_free.saturating_sub(before.double_free);
    println!(
        "{label:>8}: {} turns in {:.1}ms — {} completed / {} disconnected / {} shed / {} errors; \
         {:.0} tok/s, TTFT p99 {}µs, TPOT p99 {}µs, {} engine-cancelled, {} leaked",
        report.turns(),
        report.wall.as_secs_f64() * 1e3,
        report.completed,
        report.disconnected,
        report.shed,
        report.errors,
        report.tokens_per_sec(),
        pctl_us(&report.ttft_us, 99.0),
        pctl_us(&report.tpot_us, 99.0),
        cancelled,
        leaked,
    );
    let key = |k: &str| format!("{label}_{k}");
    results.push((key("turns"), report.turns() as f64));
    results.push((key("completed"), report.completed as f64));
    results.push((key("disconnected"), report.disconnected as f64));
    results.push((key("shed"), report.shed as f64));
    results.push((key("errors"), report.errors as f64));
    results.push((key("shed_rate"), report.shed_rate()));
    results.push((key("tokens_per_sec"), report.tokens_per_sec()));
    results.push((key("wall_us"), report.wall.as_secs_f64() * 1e6));
    results.push((key("ttft_p50_us"), pctl_us(&report.ttft_us, 50.0) as f64));
    results.push((key("ttft_p99_us"), pctl_us(&report.ttft_us, 99.0) as f64));
    results.push((key("tpot_p50_us"), pctl_us(&report.tpot_us, 50.0) as f64));
    results.push((key("tpot_p99_us"), pctl_us(&report.tpot_us, 99.0) as f64));
    results.push((key("engine_shed"), shed as f64));
    results.push((key("engine_cancelled"), cancelled as f64));
    results.push((key("leaked_blocks"), leaked as f64));
    Some((report, leaked))
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_saturation.json");
    let mut body = String::from("{\n  \"schema\": \"bench_saturation/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_saturation.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str(&format!("  \"seed\": {SEED},\n"));
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (clients, turns) = if smoke { (8, 3) } else { (16, 4) };

    println!("== saturation: {clients} clients x {turns} turns, seed {SEED} ==\n");
    let mut results = Results::new();
    results.push(("clients".into(), clients as f64));
    results.push(("turns_per_client".into(), turns as f64));

    // control: same seed, no chaos, no caps — the parity reference
    let control = run_cell(
        "control",
        LaunchConfig::preset("tiny").with_warmup(true),
        &SaturationScenario::new(SEED, clients, turns),
        &mut results,
    );

    // chaos: 25% mid-stream disconnects, a stalled worker reply window,
    // and a queued-prefill cap so overload sheds instead of queueing
    let chaos = run_cell(
        "chaos",
        LaunchConfig::preset("tiny")
            .with_warmup(true)
            .with_admission(2, 0)
            .with_faults("delay3ms@t6..9", SEED),
        &SaturationScenario::new(SEED, clients, turns).with_disconnects(0.25),
        &mut results,
    );

    if let (Some((control, leak_c)), Some((chaos, leak_h))) = (control, chaos) {
        let diffs = parity_mismatches(&control, &chaos);
        results.push(("parity".into(), if diffs.is_empty() { 1.0 } else { 0.0 }));
        println!(
            "\nparity: {}",
            if diffs.is_empty() {
                "survivor streams byte-identical to control".to_string()
            } else {
                format!("DIVERGED:\n{}", diffs.join("\n"))
            }
        );
        let leaked = leak_c + leak_h;
        write_json(&results);
        if !diffs.is_empty() || leaked > 0 {
            // the counters on disk are the evidence; fail the smoke gate
            eprintln!("FAIL: parity_diffs={} leaked={leaked}", diffs.len());
            std::process::exit(1);
        }
        return;
    }
    write_json(&results);
}
