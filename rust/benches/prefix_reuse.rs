//! Shared-prefix reuse benchmark: templated traffic (N shared templates,
//! most fresh prompts starting with one) against the same engine with
//! the prefix cache off vs on.
//!
//! The claims under test: a trie hit skips the shared prefill work
//! (prefill tokens executed drop, TTFT improves), completed streams are
//! byte-identical between the two cells (hard gate — reuse must be
//! invisible in the bytes), and no K/V block leaks in either cell (hard
//! gate, shared blocks included).
//!
//! Results land machine-readably in `BENCH_prefix.json` at the repo root
//! (regenerate with `scripts/bench_prefix.sh`; `BENCH_SMOKE=1` runs a
//! smaller client pool for CI).

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::memory::kvcache;
use energonai::runtime::find_artifacts;
use energonai::workload::loadgen::{
    parity_mismatches, pctl_us, run_saturation, LoadReport, SaturationScenario,
};
use energonai::workload::LengthDist;

type Results = Vec<(String, f64)>;

const SEED: u64 = 2209;

/// Per-cell outcome the cross-cell gates need: the stream report, the
/// leak counter, and the prompt positions the engine actually computed.
struct Cell {
    report: LoadReport,
    leaked: u64,
    prefill_toks: u64,
}

fn run_cell(
    label: &str,
    lc: LaunchConfig,
    scenario: &SaturationScenario,
    results: &mut Results,
) -> Option<Cell> {
    let before = kvcache::global_stats();
    let engine = match Engine::launch(lc) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip {label}: {e:#}");
            return None;
        }
    };
    if !engine.kv_cache_on() {
        eprintln!("skip {label}: decode artifacts missing");
        engine.shutdown();
        return None;
    }
    let max_context =
        engine.manifest.shape_points("tiny").iter().map(|&(_, s)| s).max().unwrap();
    let report = run_saturation(&engine, scenario, max_context);
    let m = engine.metrics_snapshot();
    let prefill_toks = m.prefill_tokens();
    let (hits, misses) = m.prefix_hit_counts();
    engine.shutdown();
    let after = kvcache::global_stats();
    let leaked = after.blocks_in_use.saturating_sub(before.blocks_in_use)
        + after.host_bytes.saturating_sub(before.host_bytes)
        + after.double_free.saturating_sub(before.double_free);
    // monotonic process-wide counters: per-cell deltas
    let grown = after.blocks_grown.saturating_sub(before.blocks_grown);
    let adopted = after.adopted_blocks.saturating_sub(before.adopted_blocks);
    let cow = after.cow_copies.saturating_sub(before.cow_copies);
    println!(
        "{label:>4}: {} turns in {:.1}ms — {} completed / {} errors; {:.0} tok/s; \
         TTFT p50 {}µs p99 {}µs; {} prefill toks, {} blocks grown, \
         {} hits / {} misses, {} adopted, {} cow, {} leaked",
        report.turns(),
        report.wall.as_secs_f64() * 1e3,
        report.completed,
        report.errors,
        report.tokens_per_sec(),
        pctl_us(&report.ttft_us, 50.0),
        pctl_us(&report.ttft_us, 99.0),
        prefill_toks,
        grown,
        hits,
        misses,
        adopted,
        cow,
        leaked,
    );
    let key = |k: &str| format!("{label}_{k}");
    results.push((key("turns"), report.turns() as f64));
    results.push((key("completed"), report.completed as f64));
    results.push((key("errors"), report.errors as f64));
    results.push((key("tokens_per_sec"), report.tokens_per_sec()));
    results.push((key("wall_us"), report.wall.as_secs_f64() * 1e6));
    results.push((key("ttft_p50_us"), pctl_us(&report.ttft_us, 50.0) as f64));
    results.push((key("ttft_p99_us"), pctl_us(&report.ttft_us, 99.0) as f64));
    results.push((key("tpot_p50_us"), pctl_us(&report.tpot_us, 50.0) as f64));
    results.push((key("prefill_tokens"), prefill_toks as f64));
    results.push((key("blocks_grown"), grown as f64));
    results.push((key("prefix_hits"), hits as f64));
    results.push((key("prefix_misses"), misses as f64));
    results.push((key("adopted_blocks"), adopted as f64));
    results.push((key("cow_copies"), cow as f64));
    results.push((key("leaked_blocks"), leaked as f64));
    Some(Cell { report, leaked, prefill_toks })
}

fn write_json(results: &Results) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_prefix.json");
    let mut body = String::from("{\n  \"schema\": \"bench_prefix/v1\",\n");
    body.push_str("  \"generated_by\": \"scripts/bench_prefix.sh\",\n");
    body.push_str("  \"preset\": \"tiny\",\n");
    body.push_str(&format!("  \"seed\": {SEED},\n"));
    body.push_str("  \"results\": {\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        body.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    if find_artifacts().is_err() {
        eprintln!("no AOT artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (clients, turns) = if smoke { (8, 2) } else { (16, 3) };

    // templated traffic: 3 shared 24-token templates over 90% of fresh
    // prompts, short unique suffixes — the shape a prompt-template
    // serving workload (few-shot prefixes, system prompts) produces
    let mut scenario =
        SaturationScenario::new(SEED, clients, turns).with_templates(3, 0.9, 24);
    scenario.prompt_dist = LengthDist::HeavyTail(6, 1.1);

    println!(
        "== prefix reuse: {clients} clients x {turns} turns, 3 templates x 24 toks @ 90%, \
         seed {SEED} ==\n"
    );
    let mut results = Results::new();
    results.push(("clients".into(), clients as f64));
    results.push(("turns_per_client".into(), turns as f64));
    results.push(("templates".into(), 3.0));
    results.push(("template_tokens".into(), 24.0));
    results.push(("template_pct".into(), 0.9));

    let off = run_cell(
        "off",
        LaunchConfig::preset("tiny").with_warmup(true),
        &scenario,
        &mut results,
    );
    let on = run_cell(
        "on",
        LaunchConfig::preset("tiny").with_warmup(true).with_prefix_cache(true),
        &scenario,
        &mut results,
    );

    if let (Some(off), Some(on)) = (off, on) {
        let diffs = parity_mismatches(&off.report, &on.report);
        results.push(("parity".into(), if diffs.is_empty() { 1.0 } else { 0.0 }));
        let ratio = if on.prefill_toks > 0 {
            off.prefill_toks as f64 / on.prefill_toks as f64
        } else {
            0.0
        };
        results.push(("prefill_reduction_x".into(), ratio));
        println!(
            "\nparity: {}",
            if diffs.is_empty() {
                "completed streams byte-identical across off/on".to_string()
            } else {
                format!("DIVERGED:\n{}", diffs.join("\n"))
            }
        );
        println!(
            "prefill tokens: {} off vs {} on ({ratio:.2}x reduction)",
            off.prefill_toks, on.prefill_toks
        );
        let leaked = off.leaked + on.leaked;
        write_json(&results);
        if !diffs.is_empty() || leaked > 0 {
            // the counters on disk are the evidence; fail the smoke gate
            eprintln!("FAIL: parity_diffs={} leaked={leaked}", diffs.len());
            std::process::exit(1);
        }
        return;
    }
    write_json(&results);
}
