"""L2: the transformer compute graph, built from the L1 Pallas kernels.

This file defines every AOT *variant* the Rust coordinator executes:

  embed            token + position embedding             (stage 0 of PP)
  layer_full       one pre-LN transformer layer, fused     (PP stages, TP=1)
  attn_shard       Megatron 1-D attention half of a layer  (TP workers)
  mlp_shard        Megatron 1-D MLP half                   (TP workers, DRCE)
  drce_attn_shard  attention half over the *packed* token  (DRCE, §4.3)
                   matrix, padding rebuilt only around MHA
  logits           final layernorm + tied-embedding head   (last PP stage)

Incremental-decode variants (the KV-cache path; DRCE's goal of eliminating
redundant computation, §4.2.2, applied along the *time* axis):

  embed_decode       embedding of one token per row at an explicit position
  layer_full_kv      layer_full that additionally emits the layer's K/V rows
                     (prefill of generation sessions fills the cache)
  attn_shard_kv      attn_shard that additionally emits the shard's K/V rows
  layer_full_decode  one layer over a single-position (B, 1, H) activation,
                     attending over (B, S, H) cache tensors; emits the new
                     K/V row so the host writes it into its paged cache
  attn_shard_decode  the TP half of the above (caches are (B, S, H/tp);
                     the MLP half reuses ``mlp_shard`` with rows = B)

Speculative-decode variants (draft-and-verify: score a window of K
candidate tokens against the cache in ONE pass, with causal masking
*inside* the window, so the scheduler can commit the longest accepted
prefix — tokens-per-pass > 1 at unchanged greedy semantics):

  embed_verify       embedding of K tokens per row at explicit positions
                     base .. base+K-1 (base = valid_len - K, bound host-side)
  layer_full_verify  one layer over a (B, K, H) candidate window attending
                     over (B, S, H) cache tensors; window row j sees cache
                     positions 0..base+j (its own row included); emits the
                     K new K/V rows for the host to append speculatively
  attn_shard_verify  the TP half of the above (caches are (B, S, H/tp);
                     the MLP half reuses ``mlp_shard`` with rows = B*K)

Row j of a verify window computes a plain decode step at position base+j
given the prefix — ``test_model.py::TestVerify`` pins that per-row
equivalence (to float tolerance: the two variants compile to different
fused programs, so equality is numerical, not bitwise — a near-argmax-tie
is the theoretical divergence window; the Rust differential suite pins
stream equality empirically). The seq=K ``logits`` head scores every
window row at once.

Decode attention is a (1, S) matrix-vector product per head — a different
shape regime from the flash-style prefill kernel, so it is expressed
directly in jnp (online softmax buys nothing at query length 1). The new
token's K/V row is blended into the cache at position ``valid_len - 1``
with a one-hot mask before attending, so the query sees itself; keys at or
beyond ``valid_len`` get a finite additive ``NEG_INF`` bias. NOTE: that
bias only suppresses *bounded* scores — the host must hand in zeroed
staging beyond the valid prefix (``worker.rs::kv_staging`` does), since a
NaN or huge-magnitude garbage key would survive any additive mask.

Tensor-parallel partitioning follows Megatron-LM's 1-D strategy exactly as
the paper describes (§4.1.3): the first linear of each pair is column-
split, the second row-split, so each layer needs a single all-reduce per
pair — two per layer — which the Rust coordinator performs between the
``attn_shard`` and ``mlp_shard`` executions. Shard biases of row-split
linears must be pre-divided by tp so the all-reduce sums to the full bias;
``shard_layer_params`` implements that rule and is mirrored in
``rust/src/model/shard.rs``.

Residual adds across the all-reduce boundary are performed by the
coordinator (y = r + mlp_sum, r = x + attn_sum); everything else is fused
into the executables.

All shapes are static (AOT) — the dynamic batcher on the Rust side pads
into the compiled (batch, seq) buckets, and DRCE packs into ``t_bucket``
rows (slack rows replicate row 0; see kernels/pack.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import NEG_INF, attention, layernorm, linear
from .kernels.pack import rebuild_padding, remove_padding
from .kernels.ref import causal_padding_bias


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """GPT-style geometry. ``gpt3`` matches the paper's head config."""

    name: str
    hidden: int
    n_heads: int
    vocab: int
    max_seq: int
    n_layers: int
    ffn_mult: int = 4

    @property
    def head_dim(self) -> int:
        return self.hidden // self.n_heads

    @property
    def ffn(self) -> int:
        return self.hidden * self.ffn_mult

    def params_per_layer(self) -> int:
        h, f = self.hidden, self.ffn
        return 4 * h + (h * 3 * h + 3 * h) + (h * h + h) + (h * f + f) + (f * h + h)


PRESETS = {
    # Real-execution presets (CPU PJRT):
    "tiny": ModelConfig("tiny", hidden=64, n_heads=2, vocab=128, max_seq=32, n_layers=4),
    "small": ModelConfig("small", hidden=256, n_heads=4, vocab=512, max_seq=64, n_layers=8),
    "base": ModelConfig("base", hidden=512, n_heads=8, vocab=2048, max_seq=128, n_layers=12),
    # Paper-scale configs (analytic perf model only; never AOT-compiled):
    "gpt3": ModelConfig("gpt3", hidden=12288, n_heads=96, vocab=51200, max_seq=2048, n_layers=96),
}


# ---------------------------------------------------------------------------
# Parameter specifications (the order is the executable argument order and
# is mirrored by rust/src/model/spec.rs via the manifest).
# ---------------------------------------------------------------------------

def layer_param_spec(cfg: ModelConfig, tp: int = 1):
    """[(name, shape)] for one layer's parameters under tp-way sharding."""
    h, f, nh = cfg.hidden, cfg.ffn, cfg.n_heads
    assert nh % tp == 0, f"heads {nh} not divisible by tp {tp}"
    assert f % tp == 0
    return [
        ("ln1_g", (h,)),
        ("ln1_b", (h,)),
        ("wqkv", (h, 3 * h // tp)),
        ("bqkv", (3 * h // tp,)),
        ("wo", (h // tp, h)),
        ("bo", (h,)),  # pre-divided by tp on the rust side
        ("ln2_g", (h,)),
        ("ln2_b", (h,)),
        ("w1", (h, f // tp)),
        ("b1", (f // tp,)),
        ("w2", (f // tp, h)),
        ("b2", (h,)),  # pre-divided by tp
    ]


ATTN_PARAMS = ["ln1_g", "ln1_b", "wqkv", "bqkv", "wo", "bo"]
MLP_PARAMS = ["ln2_g", "ln2_b", "w1", "b1", "w2", "b2"]


def shard_layer_params(params: dict, tp: int, rank: int, n_heads: int) -> dict:
    """Megatron 1-D shard of a full layer's params (oracle for tests; the
    production implementation lives in rust/src/model/shard.rs).

    wqkv is column-split *by head groups* so each shard computes whole
    heads; wo/w2 are row-split; biases of row-split linears are divided by
    tp so the all-reduce reconstructs them exactly once.
    """
    h = params["wqkv"].shape[0]
    hd = h // n_heads
    heads_local = n_heads // tp
    out = dict(params)

    # wqkv: (H, 3H) = concat of q|k|v each (H, H). Split each by head block.
    wq, wk, wv = jnp.split(params["wqkv"], 3, axis=1)
    bq, bk, bv = jnp.split(params["bqkv"], 3)
    sl = slice(rank * heads_local * hd, (rank + 1) * heads_local * hd)
    out["wqkv"] = jnp.concatenate([wq[:, sl], wk[:, sl], wv[:, sl]], axis=1)
    out["bqkv"] = jnp.concatenate([bq[sl], bk[sl], bv[sl]])
    out["wo"] = params["wo"][sl, :]
    out["bo"] = params["bo"] / tp
    fsl = slice(rank * (params["w1"].shape[1] // tp), (rank + 1) * (params["w1"].shape[1] // tp))
    out["w1"] = params["w1"][:, fsl]
    out["b1"] = params["b1"][fsl]
    out["w2"] = params["w2"][fsl, :]
    out["b2"] = params["b2"] / tp
    return out


# ---------------------------------------------------------------------------
# Module builders
# ---------------------------------------------------------------------------

def _mha_kv(x, bias, wqkv, bqkv, wo, bo, heads_local: int):
    """Attention core on padded (B, S, H_in) input with local heads.

    Returns ``(out, k, v)`` — k/v in the flat (B, S, heads_local * hd)
    layout the KV cache stores (head split is cheap to redo at decode).
    """
    b, s, _ = x.shape
    qkv = linear(x, wqkv, bqkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = q.shape[-1] // heads_local

    def to_heads(t):
        return t.reshape(b, s, heads_local, hd).transpose(0, 2, 1, 3)

    o = attention(to_heads(q), to_heads(k), to_heads(v), bias)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, heads_local * hd)
    return linear(o, wo, bo), k, v


def _mha(x, bias, wqkv, bqkv, wo, bo, heads_local: int):
    return _mha_kv(x, bias, wqkv, bqkv, wo, bo, heads_local)[0]


def _mha_decode(x, valid_len, k_cache, v_cache, wqkv, bqkv, wo, bo, heads_local: int):
    """Attention core for one query position per row against a cache.

    ``x`` is the layernormed (B, 1, H) activation; ``k_cache``/``v_cache``
    are (B, S, H_local) with positions ``0 .. valid_len-2`` populated;
    ``valid_len`` counts tokens *including* the one being decoded. The new
    K/V row is blended in at ``valid_len - 1`` (so the query attends to
    itself) and returned for the host to append to its cache.
    """
    b = x.shape[0]
    s = k_cache.shape[1]
    h_local = k_cache.shape[2]
    hd = h_local // heads_local
    qkv = linear(x, wqkv, bqkv)  # (B, 1, 3*H_local)
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)

    pos = valid_len - 1  # (B,)
    onehot = (jnp.arange(s)[None, :] == pos[:, None]).astype(k_cache.dtype)[:, :, None]
    k_full = k_cache * (1.0 - onehot) + k_new * onehot  # (B, S, H_local)
    v_full = v_cache * (1.0 - onehot) + v_new * onehot

    def to_heads(t, n):
        return t.reshape(b, n, heads_local, hd).transpose(0, 2, 1, 3)

    qh = to_heads(q, 1).astype(jnp.float32)  # (B, nh, 1, hd)
    kh = to_heads(k_full, s).astype(jnp.float32)  # (B, nh, S, hd)
    vh = to_heads(v_full, s).astype(jnp.float32)
    keymask = jnp.arange(s)[None, :] < valid_len[:, None]  # (B, S)
    bias = jnp.where(keymask, 0.0, NEG_INF)[:, None, None, :]  # (B, 1, 1, S)
    scale = 1.0 / (hd**0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * scale + bias
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vh).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h_local)
    return linear(o, wo, bo), k_new, v_new


def _mha_verify(x, valid_len, k_cache, v_cache, wqkv, bqkv, wo, bo, heads_local: int):
    """Attention core for a K-position candidate window against a cache.

    ``x`` is the layernormed (B, K, H) activation of the window tokens;
    ``k_cache``/``v_cache`` are (B, S, H_local) with positions
    ``0 .. base-1`` populated, where ``base = valid_len - K`` and
    ``valid_len`` counts tokens *including* the whole window. The K new
    K/V rows are blended in at positions ``base + j`` before attending,
    and window query j sees exactly keys ``0 .. base+j`` (causal masking
    inside the window) — so row j reproduces a plain decode step at
    position ``base + j``. The new rows are returned for the host to
    append speculatively (and truncate back to the accepted prefix).
    """
    b, k_win = x.shape[0], x.shape[1]
    s = k_cache.shape[1]
    h_local = k_cache.shape[2]
    hd = h_local // heads_local
    qkv = linear(x, wqkv, bqkv)  # (B, K, 3*H_local)
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)

    base = valid_len - k_win  # (B,)
    # scatter the window rows into the cache: position base+j <- row j
    pos = base[:, None] + jnp.arange(k_win)[None, :]  # (B, K)
    onehot = (jnp.arange(s)[None, :, None] == pos[:, None, :]).astype(k_cache.dtype)  # (B, S, K)
    in_window = jnp.sum(onehot, axis=-1, keepdims=True)  # (B, S, 1) 0/1
    k_full = k_cache * (1.0 - in_window) + jnp.einsum("bsj,bjh->bsh", onehot, k_new)
    v_full = v_cache * (1.0 - in_window) + jnp.einsum("bsj,bjh->bsh", onehot, v_new)

    def to_heads(t, n):
        return t.reshape(b, n, heads_local, hd).transpose(0, 2, 1, 3)

    qh = to_heads(q, k_win).astype(jnp.float32)  # (B, nh, K, hd)
    kh = to_heads(k_full, s).astype(jnp.float32)  # (B, nh, S, hd)
    vh = to_heads(v_full, s).astype(jnp.float32)
    # query j (position base+j) attends keys at positions <= base+j
    keymask = jnp.arange(s)[None, None, :] <= pos[:, :, None]  # (B, K, S)
    bias = jnp.where(keymask, 0.0, NEG_INF)[:, None, :, :]  # (B, 1, K, S)
    scale = 1.0 / (hd**0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * scale + bias
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vh).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, k_win, h_local)
    return linear(o, wo, bo), k_new, v_new


def build_layer_full(cfg: ModelConfig) -> Callable:
    """Whole layer, single device: y = r + mlp(ln2(r)), r = x + attn(ln1(x))."""

    def fn(x, valid_len, ln1_g, ln1_b, wqkv, bqkv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2):
        bias = causal_padding_bias(valid_len, x.shape[1])
        a = layernorm(x, ln1_g, ln1_b)
        attn = _mha(a, bias, wqkv, bqkv, wo, bo, cfg.n_heads)
        r = x + attn
        m = layernorm(r, ln2_g, ln2_b)
        m = linear(m, w1, b1, act="gelu")
        m = linear(m, w2, b2)
        return (r + m,)

    return fn


def build_attn_shard(cfg: ModelConfig, tp: int) -> Callable:
    """Attention half of a layer on one TP worker.

    Returns the *partial* attention output (no residual): the coordinator
    all-reduces partials across the tp group and adds the residual.
    """
    heads_local = cfg.n_heads // tp

    def fn(x, valid_len, ln1_g, ln1_b, wqkv, bqkv, wo, bo):
        bias = causal_padding_bias(valid_len, x.shape[1])
        a = layernorm(x, ln1_g, ln1_b)
        return (_mha(a, bias, wqkv, bqkv, wo, bo, heads_local),)

    return fn


def build_mlp_shard(cfg: ModelConfig, tp: int) -> Callable:
    """MLP half on one TP worker over a (rows, H) matrix (padded or packed).

    Input is r = x + attn_sum (computed by the coordinator after the
    attention all-reduce); output is the partial MLP result.
    """

    def fn(r, ln2_g, ln2_b, w1, b1, w2, b2):
        m = layernorm(r, ln2_g, ln2_b)
        m = linear(m, w1, b1, act="gelu")
        return (linear(m, w2, b2),)

    return fn


def build_drce_attn_shard(cfg: ModelConfig, tp: int, batch: int, seq: int, t_bucket: int) -> Callable:
    """DRCE attention half (§4.3): all linears run on the packed (T, H)
    token matrix; padding is rebuilt only around the multi-head attention
    structure via the index maps the engine broadcasts with the command.
    """
    heads_local = cfg.n_heads // tp
    h = cfg.hidden
    hd = cfg.head_dim

    def fn(x_packed, valid_len, unpad_map, pad_map, ln1_g, ln1_b, wqkv, bqkv, wo, bo):
        bias = causal_padding_bias(valid_len, seq)
        a = layernorm(x_packed, ln1_g, ln1_b)  # packed rows
        qkv_packed = linear(a, wqkv, bqkv)  # (T, 3H/tp)
        qkv = rebuild_padding(qkv_packed, pad_map)  # (B*S, 3H/tp)
        q, k, v = jnp.split(qkv.reshape(batch, seq, 3 * h // tp), 3, axis=-1)

        def to_heads(t):
            return t.reshape(batch, seq, heads_local, hd).transpose(0, 2, 1, 3)

        o = attention(to_heads(q), to_heads(k), to_heads(v), bias)
        o = o.transpose(0, 2, 1, 3).reshape(batch * seq, heads_local * hd)
        o_packed = remove_padding(o, unpad_map)  # (T, H/tp)
        return (linear(o_packed, wo, bo),)

    return fn


def build_layer_full_kv(cfg: ModelConfig) -> Callable:
    """`layer_full` that also emits the layer's K/V rows (B, S, H) so the
    coordinator can seed a generation session's cache during prefill."""

    def fn(x, valid_len, ln1_g, ln1_b, wqkv, bqkv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2):
        bias = causal_padding_bias(valid_len, x.shape[1])
        a = layernorm(x, ln1_g, ln1_b)
        attn, k, v = _mha_kv(a, bias, wqkv, bqkv, wo, bo, cfg.n_heads)
        r = x + attn
        m = layernorm(r, ln2_g, ln2_b)
        m = linear(m, w1, b1, act="gelu")
        m = linear(m, w2, b2)
        return (r + m, k, v)

    return fn


def build_attn_shard_kv(cfg: ModelConfig, tp: int) -> Callable:
    """`attn_shard` that also emits the shard's K/V rows (B, S, H/tp)."""
    heads_local = cfg.n_heads // tp

    def fn(x, valid_len, ln1_g, ln1_b, wqkv, bqkv, wo, bo):
        bias = causal_padding_bias(valid_len, x.shape[1])
        a = layernorm(x, ln1_g, ln1_b)
        return _mha_kv(a, bias, wqkv, bqkv, wo, bo, heads_local)

    return fn


def build_layer_full_decode(cfg: ModelConfig) -> Callable:
    """One layer over a single-position activation against the KV cache.

    Inputs: x (B, 1, H), valid_len (B,) counting the current token,
    k_cache/v_cache (B, S, H). Outputs: (y, k_new, v_new) with the new
    K/V row (B, 1, H) for the host to append.
    """

    def fn(x, valid_len, k_cache, v_cache, ln1_g, ln1_b, wqkv, bqkv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2):
        a = layernorm(x, ln1_g, ln1_b)
        attn, k_new, v_new = _mha_decode(
            a, valid_len, k_cache, v_cache, wqkv, bqkv, wo, bo, cfg.n_heads
        )
        r = x + attn
        m = layernorm(r, ln2_g, ln2_b)
        m = linear(m, w1, b1, act="gelu")
        m = linear(m, w2, b2)
        return (r + m, k_new, v_new)

    return fn


def build_attn_shard_decode(cfg: ModelConfig, tp: int) -> Callable:
    """TP attention half of a decode step: partial output (B, 1, H) plus
    the shard's new K/V row (B, 1, H/tp). The coordinator all-reduces the
    partial, adds the residual, and runs ``mlp_shard`` with rows = B."""
    heads_local = cfg.n_heads // tp

    def fn(x, valid_len, k_cache, v_cache, ln1_g, ln1_b, wqkv, bqkv, wo, bo):
        a = layernorm(x, ln1_g, ln1_b)
        return _mha_decode(a, valid_len, k_cache, v_cache, wqkv, bqkv, wo, bo, heads_local)

    return fn


def build_layer_full_verify(cfg: ModelConfig) -> Callable:
    """One layer over a K-token candidate window against the KV cache.

    Inputs: x (B, K, H), valid_len (B,) counting every window token,
    k_cache/v_cache (B, S, H). Outputs: (y, k_new, v_new) with the K new
    K/V rows (B, K, H) the host appends speculatively.
    """

    def fn(x, valid_len, k_cache, v_cache, ln1_g, ln1_b, wqkv, bqkv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2):
        a = layernorm(x, ln1_g, ln1_b)
        attn, k_new, v_new = _mha_verify(
            a, valid_len, k_cache, v_cache, wqkv, bqkv, wo, bo, cfg.n_heads
        )
        r = x + attn
        m = layernorm(r, ln2_g, ln2_b)
        m = linear(m, w1, b1, act="gelu")
        m = linear(m, w2, b2)
        return (r + m, k_new, v_new)

    return fn


def build_attn_shard_verify(cfg: ModelConfig, tp: int) -> Callable:
    """TP attention half of a verify step: partial output (B, K, H) plus
    the shard's new K/V rows (B, K, H/tp). The coordinator all-reduces the
    partial, adds the residual, and runs ``mlp_shard`` with rows = B*K."""
    heads_local = cfg.n_heads // tp

    def fn(x, valid_len, k_cache, v_cache, ln1_g, ln1_b, wqkv, bqkv, wo, bo):
        a = layernorm(x, ln1_g, ln1_b)
        return _mha_verify(a, valid_len, k_cache, v_cache, wqkv, bqkv, wo, bo, heads_local)

    return fn


def build_embed_verify(cfg: ModelConfig) -> Callable:
    """Embedding of K tokens per row at explicit consecutive positions
    ``pos + j`` (the verify window starts at ``valid_len - K``, bound
    host-side as ``pos``)."""

    def fn(ids, pos, wte, wpe):
        k_win = ids.shape[1]
        positions = pos[:, None] + jnp.arange(k_win)[None, :]  # (B, K)
        return (jnp.take(wte, ids, axis=0) + wpe[positions],)

    return fn


def build_embed_decode(cfg: ModelConfig) -> Callable:
    """Embedding of one token per row at an explicit position (the decode
    step's position is ``valid_len - 1``, bound host-side)."""

    def fn(ids, pos, wte, wpe):
        return (jnp.take(wte, ids, axis=0) + wpe[pos][:, None, :],)

    return fn


def build_embed(cfg: ModelConfig) -> Callable:
    def fn(ids, wte, wpe):
        s = ids.shape[1]
        return (jnp.take(wte, ids, axis=0) + wpe[jnp.arange(s)][None, :, :],)

    return fn


def build_logits(cfg: ModelConfig) -> Callable:
    """Final layernorm + tied-embedding LM head."""

    def fn(x, lnf_g, lnf_b, wte):
        y = layernorm(x, lnf_g, lnf_b)
        z = jnp.einsum("bsh,vh->bsv", y.astype(jnp.float32), wte.astype(jnp.float32))
        return (z,)

    return fn


# ---------------------------------------------------------------------------
# Variant registry: everything aot.py can lower, with example shapes.
# ---------------------------------------------------------------------------

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def variant(cfg: ModelConfig, kind: str, *, batch: int = 1, seq: int = 16, tp: int = 1, t_bucket: int = 0):
    """Return (name, fn, [(arg_name, ShapeDtypeStruct)]) for one variant."""
    h, f = cfg.hidden, cfg.ffn
    lp = dict(layer_param_spec(cfg, tp))

    def params(names):
        return [(n, _spec(lp[n])) for n in names]

    if kind == "embed":
        name = f"{cfg.name}_embed_b{batch}_s{seq}"
        args = [
            ("ids", _spec((batch, seq), I32)),
            ("wte", _spec((cfg.vocab, h))),
            ("wpe", _spec((cfg.max_seq, h))),
        ]
        return name, build_embed(cfg), args
    if kind == "layer_full":
        name = f"{cfg.name}_layer_full_b{batch}_s{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("valid_len", _spec((batch,), I32)),
        ] + params(ATTN_PARAMS + MLP_PARAMS)
        return name, build_layer_full(cfg), args
    if kind == "attn_shard":
        name = f"{cfg.name}_attn_shard_tp{tp}_b{batch}_s{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("valid_len", _spec((batch,), I32)),
        ] + params(ATTN_PARAMS)
        return name, build_attn_shard(cfg, tp), args
    if kind == "mlp_shard":
        rows = t_bucket if t_bucket else batch * seq
        name = f"{cfg.name}_mlp_shard_tp{tp}_r{rows}"
        args = [("r", _spec((rows, h)))] + params(MLP_PARAMS)
        return name, build_mlp_shard(cfg, tp), args
    if kind == "layer_full_kv":
        name = f"{cfg.name}_layer_full_kv_b{batch}_s{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("valid_len", _spec((batch,), I32)),
        ] + params(ATTN_PARAMS + MLP_PARAMS)
        return name, build_layer_full_kv(cfg), args
    if kind == "attn_shard_kv":
        name = f"{cfg.name}_attn_shard_kv_tp{tp}_b{batch}_s{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("valid_len", _spec((batch,), I32)),
        ] + params(ATTN_PARAMS)
        return name, build_attn_shard_kv(cfg, tp), args
    if kind == "layer_full_decode":
        # cache capacity is always max_seq; the name needs only the width
        name = f"{cfg.name}_layer_full_decode_b{batch}"
        args = [
            ("x", _spec((batch, 1, h))),
            ("valid_len", _spec((batch,), I32)),
            ("k_cache", _spec((batch, cfg.max_seq, h))),
            ("v_cache", _spec((batch, cfg.max_seq, h))),
        ] + params(ATTN_PARAMS + MLP_PARAMS)
        return name, build_layer_full_decode(cfg), args
    if kind == "attn_shard_decode":
        name = f"{cfg.name}_attn_shard_decode_tp{tp}_b{batch}"
        args = [
            ("x", _spec((batch, 1, h))),
            ("valid_len", _spec((batch,), I32)),
            ("k_cache", _spec((batch, cfg.max_seq, h // tp))),
            ("v_cache", _spec((batch, cfg.max_seq, h // tp))),
        ] + params(ATTN_PARAMS)
        return name, build_attn_shard_decode(cfg, tp), args
    if kind == "layer_full_verify":
        # the verify window size rides in `seq`; cache capacity is max_seq
        name = f"{cfg.name}_layer_full_verify_b{batch}_k{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("valid_len", _spec((batch,), I32)),
            ("k_cache", _spec((batch, cfg.max_seq, h))),
            ("v_cache", _spec((batch, cfg.max_seq, h))),
        ] + params(ATTN_PARAMS + MLP_PARAMS)
        return name, build_layer_full_verify(cfg), args
    if kind == "attn_shard_verify":
        name = f"{cfg.name}_attn_shard_verify_tp{tp}_b{batch}_k{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("valid_len", _spec((batch,), I32)),
            ("k_cache", _spec((batch, cfg.max_seq, h // tp))),
            ("v_cache", _spec((batch, cfg.max_seq, h // tp))),
        ] + params(ATTN_PARAMS)
        return name, build_attn_shard_verify(cfg, tp), args
    if kind == "embed_verify":
        name = f"{cfg.name}_embed_verify_b{batch}_k{seq}"
        args = [
            ("ids", _spec((batch, seq), I32)),
            ("pos", _spec((batch,), I32)),
            ("wte", _spec((cfg.vocab, h))),
            ("wpe", _spec((cfg.max_seq, h))),
        ]
        return name, build_embed_verify(cfg), args
    if kind == "embed_decode":
        name = f"{cfg.name}_embed_decode_b{batch}"
        args = [
            ("ids", _spec((batch, 1), I32)),
            ("pos", _spec((batch,), I32)),
            ("wte", _spec((cfg.vocab, h))),
            ("wpe", _spec((cfg.max_seq, h))),
        ]
        return name, build_embed_decode(cfg), args
    if kind == "drce_attn_shard":
        assert t_bucket > 0
        name = f"{cfg.name}_drce_attn_shard_tp{tp}_b{batch}_s{seq}_t{t_bucket}"
        args = [
            ("x_packed", _spec((t_bucket, h))),
            ("valid_len", _spec((batch,), I32)),
            ("unpad_map", _spec((t_bucket,), I32)),
            ("pad_map", _spec((batch * seq,), I32)),
        ] + params(ATTN_PARAMS)
        return name, build_drce_attn_shard(cfg, tp, batch, seq, t_bucket), args
    if kind == "logits":
        name = f"{cfg.name}_logits_b{batch}_s{seq}"
        args = [
            ("x", _spec((batch, seq, h))),
            ("lnf_g", _spec((h,))),
            ("lnf_b", _spec((h,))),
            ("wte", _spec((cfg.vocab, h))),
        ]
        return name, build_logits(cfg), args
    raise ValueError(f"unknown variant kind {kind!r}")
