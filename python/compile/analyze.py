"""L1/L2 performance analysis (the build-time half of the §Perf pass).

interpret=True wallclock is CPU-numpy time, NOT a TPU proxy — so the L1/L2
optimization targets are structural:

  L2 (HLO): op counts per lowered variant — fusion opportunities left on
     the table show up as long chains of elementwise ops between GEMMs;
     XLA fuses those post-compile, but the pre-fusion op mix indicates how
     much non-GEMM work each variant carries (the Fig. 2 argument).

  L1 (Pallas): per-kernel VMEM footprint + MXU utilization estimates from
     the BlockSpec geometry — the numbers a Mosaic compiler would care
     about. Targets: fit in ~16 MiB VMEM with double-buffering headroom,
     and keep the MXU k-dimension ≥ the 128×128 systolic tile.

Usage:  cd python && python -m compile.analyze [--out ../artifacts]
"""

from __future__ import annotations

import argparse
import os
import re
import sys

from .model import PRESETS

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on modern TPUs
MXU = 128  # systolic array dimension


def hlo_op_stats(path: str) -> dict:
    """Count HLO opcodes in an .hlo.txt artifact."""
    ops: dict = {}
    opcode = re.compile(r"=\s*[a-z0-9\[\]{}_,\s]*?([a-z][a-z0-9-]*)\(")
    with open(path) as f:
        for line in f:
            line = line.strip()
            if "=" not in line or line.startswith(("HloModule", "ENTRY", "%", "}")):
                continue
            m = opcode.search(line)
            if m:
                ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return ops


def classify(ops: dict) -> dict:
    gemm = sum(v for k, v in ops.items() if k in ("dot", "convolution"))
    elementwise = sum(
        v
        for k, v in ops.items()
        if k in ("add", "multiply", "subtract", "divide", "maximum", "exponential", "tanh", "rsqrt", "negate", "power")
    )
    data_movement = sum(
        v for k, v in ops.items() if k in ("reshape", "transpose", "broadcast", "slice", "concatenate", "gather", "copy")
    )
    reduce = sum(v for k, v in ops.items() if k.startswith("reduce"))
    return {
        "total": sum(ops.values()),
        "dot": gemm,
        "elementwise": elementwise,
        "data_movement": data_movement,
        "reduce": reduce,
    }


def matmul_kernel_estimate(m: int, k: int, n: int, block_m: int, block_n: int, block_k: int, dtype_bytes: int = 4):
    """VMEM + MXU estimates for the fused_mlp tiled matmul BlockSpec."""
    # per grid step: A stripe (block_m, K), W stripe (K, block_n),
    # bias (1, block_n), output tile (block_m, block_n), accumulator
    vmem = dtype_bytes * (block_m * k + k * block_n + block_n + 2 * block_m * block_n)
    # MXU utilization: how full each (128,128,128) pass is
    mxu_util = min(block_m / MXU, 1.0) * min(block_n / MXU, 1.0) * min(block_k / MXU, 1.0)
    return vmem, mxu_util


def attention_kernel_estimate(seq: int, head_dim: int, block_q: int, block_k: int, dtype_bytes: int = 4):
    """VMEM + MXU estimates for the flash attention BlockSpec."""
    # per grid step: q tile, k/v stripes, bias stripe, running stats, acc
    vmem = dtype_bytes * (
        block_q * head_dim  # q
        + 2 * seq * head_dim  # k, v stripes
        + block_q * seq  # bias stripe
        + 2 * block_q  # m, l
        + block_q * head_dim  # acc
    )
    mxu_util = min(block_q / MXU, 1.0) * min(head_dim / MXU, 1.0)
    return vmem, mxu_util


def report_l1() -> str:
    out = ["L1 Pallas kernel estimates (VMEM footprint / MXU utilization)", ""]
    out.append(f"{'kernel':<44}{'VMEM':>12}{'fits16M':>9}{'MXU util':>10}")
    # geometries: the shapes the AOT plan actually compiles + GPT-3 scale
    for (label, m, k, n, bm, bn, bk) in [
        ("mlp fc1 tiny  (32x64 @ 64x256, blk 32/128/64)", 32, 64, 256, 32, 128, 64),
        ("mlp fc1 small (256x256 @ 256x1024)", 256, 256, 1024, 64, 128, 256),
        ("mlp fc1 gpt3  (2048x12288 @ 12288x49152)", 2048, 12288, 49152, 128, 128, 256),
    ]:
        vmem, util = matmul_kernel_estimate(m, k, n, bm, bn, bk)
        out.append(f"{label:<44}{vmem/1024/1024:>9.2f} MiB{str(vmem <= VMEM_BYTES):>7}{util:>9.2f}")
    for (label, s, hd, bq, bk2) in [
        ("attention tiny  (S=16, hd=32)", 16, 32, 16, 16),
        ("attention small (S=64, hd=64)", 64, 64, 32, 32),
        ("attention gpt3  (S=2048, hd=128)", 2048, 128, 128, 128),
    ]:
        vmem, util = attention_kernel_estimate(s, hd, bq, bk2)
        out.append(f"{label:<44}{vmem/1024/1024:>9.2f} MiB{str(vmem <= VMEM_BYTES):>7}{util:>9.2f}")
    out.append("")
    out.append("note: gpt3 attention K/V stripes exceed a single VMEM residency at")
    out.append("S=2048 — the flash loop streams them in block_k chunks, so resident")
    out.append("set = q tile + 2 chunks + stats, well under 16 MiB.")
    return "\n".join(out)


def report_l2(artifacts: str) -> str:
    out = ["", "L2 HLO op mix per variant (post-lowering, pre-XLA-fusion)", ""]
    out.append(f"{'variant':<44}{'total':>7}{'dot':>6}{'elem':>7}{'move':>7}{'reduce':>8}")
    import json

    with open(os.path.join(artifacts, "manifest.json")) as f:
        manifest = json.load(f)
    interesting = [
        v for v in manifest["variants"]
        if v["preset"] == "tiny" or (v["preset"] == "small" and v["kind"] == "layer_full")
    ]
    for v in interesting[:14]:
        path = os.path.join(artifacts, v["file"])
        if not os.path.exists(path):
            continue
        c = classify(hlo_op_stats(path))
        out.append(
            f"{v['name']:<44}{c['total']:>7}{c['dot']:>6}{c['elementwise']:>7}{c['data_movement']:>7}{c['reduce']:>8}"
        )
    out.append("")
    out.append("dot count per layer_full = 6 projection/MLP GEMMs + 2 attention")
    out.append("GEMMs per head-block grid step; elementwise/move ops fuse under XLA.")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args(argv)
    print(report_l1())
    print(report_l2(args.out))
    # sanity: presets resolvable
    assert "tiny" in PRESETS
    return 0


if __name__ == "__main__":
    sys.exit(main())
