"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .attention import attention, NEG_INF
from .fused_mlp import linear, matmul_bias_act
from .layernorm import layernorm
from .pack import gather_rows, make_maps, rebuild_padding, remove_padding

__all__ = [
    "attention",
    "NEG_INF",
    "linear",
    "matmul_bias_act",
    "layernorm",
    "gather_rows",
    "make_maps",
    "rebuild_padding",
    "remove_padding",
]
