"""DRCE pad-removal / pad-rebuild Pallas kernels (§4.3).

The paper binds two CUDA kernels that fuse transpose+pad to switch between
the padded layout (batch, seq, hidden) the attention module needs and the
packed layout (valid_tokens, hidden) the linear layers run on. Our row-
major layout needs no transpose, so the pair reduces to an index-driven
row gather — pad removal gathers valid rows into a packed matrix, and pad
rebuild is the *same* gather through an inverse map into a table with one
extra all-zero sentinel row (scatter expressed as gather, which is how a
TPU would express it too: dynamic row loads from HBM into VMEM tiles).

The engine broadcasts per-batch sequence lengths with the command (§4.3),
and the Rust coordinator materializes both index maps host-side; see
``rust/src/tensor/drce.rs`` for the mirror implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_rows_kernel(src_ref, idx_ref, o_ref):
    """o[j] = src[idx[j]] for one block of output rows."""
    src = src_ref[...]
    idx = idx_ref[...]
    o_ref[...] = src[idx]


def _pick_block(n: int, candidates=(64, 32, 16, 8, 4, 2, 1)) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return 1


def gather_rows(
    src: jax.Array,
    idx: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Row gather: ``out[j] = src[idx[j]]``. src: (N, H), idx: (M,) int32."""
    n, h = src.shape
    (m,) = idx.shape
    if block_rows is None:
        block_rows = _pick_block(m)
    assert m % block_rows == 0
    grid = (m // block_rows,)

    return pl.pallas_call(
        _gather_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, h), lambda i: (0, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h), src.dtype),
        interpret=interpret,
    )(src, idx)


def remove_padding(x_flat: jax.Array, unpad_map: jax.Array) -> jax.Array:
    """Padded (batch*seq, H) -> packed (T, H). ``unpad_map``: (T,) flat
    positions of the valid tokens, in batch-major order of arrival."""
    return gather_rows(x_flat, unpad_map)


def rebuild_padding(packed: jax.Array, pad_map: jax.Array) -> jax.Array:
    """Packed (T, H) -> padded (batch*seq, H). ``pad_map``: (batch*seq,)
    with pad_map[i] = packed row for position i, or T (sentinel) for pad
    positions, which selects the appended zero row."""
    t, h = packed.shape
    table = jnp.concatenate([packed, jnp.zeros((1, h), packed.dtype)], axis=0)
    return gather_rows(table, pad_map)


def make_maps(valid_lens, seq: int, t_bucket: int):
    """Host-side (numpy) helper mirrored in Rust: build (unpad_map, pad_map,
    n_valid) for a batch with per-sequence valid lengths, packing into a
    ``t_bucket``-row packed matrix (bucketed static shape for AOT).

    Overflow tokens beyond t_bucket are an error; slack rows replicate row
    0 in unpad_map (harmless compute, standard shape-bucketing trick) and
    are never referenced by pad_map.
    """
    import numpy as np

    batch = len(valid_lens)
    total = int(sum(valid_lens))
    if total > t_bucket:
        raise ValueError(f"{total} valid tokens exceed bucket {t_bucket}")
    unpad = np.zeros(t_bucket, dtype=np.int32)
    pad = np.full(batch * seq, t_bucket, dtype=np.int32)  # sentinel
    j = 0
    for b, vl in enumerate(valid_lens):
        for s in range(int(vl)):
            flat = b * seq + s
            unpad[j] = flat
            pad[flat] = j
            j += 1
    return unpad, pad, total
