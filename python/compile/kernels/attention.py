"""Fused multi-head attention Pallas kernel (flash-style, single pass).

The paper keeps a fused multi-head-attention block per layer and DRCE
(§4.3) rebuilds padding *only* around this module because attention mixes
tokens within a sequence — linears do not. FasterTransformer's fused MHA
(layernorm + QKV GEMMs + bias folded together, §5.5) is the CUDA analogue.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's threadblock-
per-(batch, head) CUDA decomposition becomes a Pallas grid over
(batch*heads, query blocks); each grid step holds a (block_q, head_dim)
query panel in VMEM and streams K/V in ``block_k`` chunks with the
online-softmax recurrence, so the S×S score matrix never materializes in
HBM. Q·Kᵀ and P·V hit the MXU; the rescaling runs on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9  # finite: fully-masked pad rows must not produce NaNs


def _attention_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, block_k: int, scale: float):
    """One (block_q, head_dim) output tile for one (batch, head)."""
    q = q_ref[0].astype(jnp.float32) * scale  # (block_q, hd)
    seq = k_ref.shape[1]
    block_q, head_dim = q.shape

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        kk = pl.load(k_ref, (0, pl.ds(i * block_k, block_k), slice(None)))
        vv = pl.load(v_ref, (0, pl.ds(i * block_k, block_k), slice(None)))
        bb = pl.load(bias_ref, (0, slice(None), pl.ds(i * block_k, block_k)))
        s = (
            jnp.dot(q, kk.astype(jnp.float32).T, preferred_element_type=jnp.float32)
            + bb.astype(jnp.float32)
        )  # (block_q, block_k)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(
            p, vv.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, seq // block_k, body, (m0, l0, acc0))
    # Fully-masked rows (pure padding) have tiny l; guard the divide.
    l = jnp.maximum(l, 1e-30)
    o_ref[0, ...] = (acc / l).astype(o_ref.dtype)


def _pick_block(n: int, candidates=(128, 64, 32, 16, 8, 4, 2, 1)) -> int:
    # 128 first: full MXU tile when the sequence allows it (§Perf L1)
    for c in candidates:
        if n % c == 0:
            return c
    return 1


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array,
    *,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """softmax(q·kᵀ/√d + bias)·v per (batch, head).

    q/k/v: (batch, heads, seq, head_dim); bias: (batch, seq, seq) additive
    mask (0 where attending is allowed, ``NEG_INF`` where not) shared
    across heads — causal + padding masks are built by the L2 model.
    """
    batch, heads, seq, head_dim = q.shape
    assert k.shape == v.shape == q.shape, (q.shape, k.shape, v.shape)
    assert bias.shape == (batch, seq, seq), bias.shape
    if block_q is None:
        block_q = _pick_block(seq)
    if block_k is None:
        block_k = _pick_block(seq)
    assert seq % block_q == 0 and seq % block_k == 0

    bh = batch * heads
    q3 = q.reshape(bh, seq, head_dim)
    k3 = k.reshape(bh, seq, head_dim)
    v3 = v.reshape(bh, seq, head_dim)
    scale = 1.0 / (head_dim**0.5)
    grid = (bh, seq // block_q)

    out = pl.pallas_call(
        functools.partial(_attention_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda b, i: (b, 0, 0)),
            # bias indexed by batch = b // heads; shared across heads
            pl.BlockSpec((1, block_q, seq), lambda b, i, heads=heads: (b // heads, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, head_dim), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, bias)
    return out.reshape(batch, heads, seq, head_dim)
