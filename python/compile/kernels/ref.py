"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts each kernel's
output matches its oracle with ``assert_allclose`` across a hypothesis
shape/dtype sweep (python/tests/test_kernels.py), and the L2 model is
additionally checked end-to-end against ``layer_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def layernorm_ref(x, gain, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps) * gain + bias
    return y.astype(x.dtype)


def matmul_bias_act_ref(x, w, b, act="none"):
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if act == "gelu":
        z = jax.nn.gelu(z)
    elif act == "relu":
        z = jnp.maximum(z, 0.0)
    return z.astype(x.dtype)


def linear_ref(x, w, b, act="none"):
    orig = x.shape
    rows = 1
    for d in orig[:-1]:
        rows *= d
    y = matmul_bias_act_ref(x.reshape(rows, orig[-1]), w, b, act)
    return y.reshape(orig[:-1] + (w.shape[1],))


def attention_ref(q, k, v, bias):
    """q/k/v: (B, nh, S, hd); bias: (B, S, S) additive mask."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale + bias[:, None, :, :].astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def gather_rows_ref(src, idx):
    return src[idx]


def causal_padding_bias(valid_len, seq):
    """(B,) valid lengths -> (B, S, S) additive causal+padding mask."""
    i = jnp.arange(seq)
    causal = (i[None, :] <= i[:, None]).astype(jnp.float32)  # (S, S)
    keymask = (i[None, None, :] < valid_len[:, None, None]).astype(jnp.float32)
    allowed = causal[None, :, :] * keymask
    return (1.0 - allowed) * NEG_INF


def mha_ref(x, valid_len, wqkv, bqkv, wo, bo, n_heads):
    """Full multi-head attention module on padded (B, S, H) input."""
    b, s, h = x.shape
    hd = h // n_heads
    qkv = linear_ref(x, wqkv, bqkv)  # (B, S, 3H)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    bias = causal_padding_bias(valid_len, s)
    o = attention_ref(heads(q), heads(k), heads(v), bias)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h)
    return linear_ref(o, wo, bo)


def layer_ref(x, valid_len, p, n_heads):
    """Pre-LN transformer layer oracle. ``p`` is the 12-entry param dict."""
    a = layernorm_ref(x, p["ln1_g"], p["ln1_b"])
    attn = mha_ref(a, valid_len, p["wqkv"], p["bqkv"], p["wo"], p["bo"], n_heads)
    r = x + attn
    m = layernorm_ref(r, p["ln2_g"], p["ln2_b"])
    m = linear_ref(m, p["w1"], p["b1"], act="gelu")
    m = linear_ref(m, p["w2"], p["b2"])
    return r + m


def embed_ref(ids, wte, wpe):
    b, s = ids.shape
    return wte[ids] + wpe[jnp.arange(s)][None, :, :]


def logits_ref(x, lnf_g, lnf_b, wte):
    y = layernorm_ref(x, lnf_g, lnf_b)
    return jnp.einsum("bsh,vh->bsv", y.astype(jnp.float32), wte.astype(jnp.float32))
