"""Tiled matmul + bias + activation Pallas kernel.

This is the GEMM hot-spot of the paper: Fig. 2 shows GEMM kernels take
62%→96% of layer time as GPT scales from 125M to 175B. EnergonAI's MLP
module is two of these back to back (fc1 + GELU, fc2), and DRCE (§4.3)
runs them over the *packed* token matrix with padding removed.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
into (block_m, block_n) MXU-sized panels held in VMEM; the kernel streams
K in ``block_k`` chunks from the operand stripes — the structure a Mosaic
compiler double-buffers HBM→VMEM. The epilogue (bias add + GELU) is fused
into the same kernel so the activation never round-trips to HBM, which is
exactly the fusion FasterTransformer does in CUDA (§5.5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = ("none", "gelu", "relu")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, block_k: int, act: str):
    """One (block_m, block_n) output tile; stream K in block_k chunks."""
    block_m = x_ref.shape[0]
    block_n = w_ref.shape[1]
    k_total = x_ref.shape[1]
    acc = jnp.zeros((block_m, block_n), jnp.float32)

    def body(i, acc):
        xk = pl.load(x_ref, (slice(None), pl.ds(i * block_k, block_k)))
        wk = pl.load(w_ref, (pl.ds(i * block_k, block_k), slice(None)))
        return acc + jnp.dot(
            xk.astype(jnp.float32),
            wk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(0, k_total // block_k, body, acc)
    z = acc + b_ref[...].astype(jnp.float32)
    if act == "gelu":
        z = jax.nn.gelu(z)
    elif act == "relu":
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z.astype(o_ref.dtype)


def _pick_block(n: int, candidates) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return 1


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "none",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """``act(x @ w + b)`` with a tiled Pallas kernel.

    x: (M, K), w: (K, N), b: (N,). M is padded up to the block size and
    sliced back, so any M works; K and N must divide by their blocks
    (true for all transformer geometries used here).
    """
    assert act in _ACTS, act
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), b.shape

    if block_m is None:
        # prefer a full 128-row MXU tile (§Perf L1: raises systolic-array
        # utilization from 0.5 to 1.0 at GPT-3 scale); smaller M falls back
        block_m = _pick_block(m, (128, 64, 32, 16, 8, 4, 2, 1))
    if block_n is None:
        block_n = _pick_block(n, (128, 64, 32, 16, 8, 4, 2, 1))
    if block_k is None:
        block_k = _pick_block(k, (256, 128, 64, 32, 16, 8, 4, 2, 1))

    pad_m = (-m) % block_m
    if pad_m:
        x = jnp.concatenate([x, jnp.zeros((pad_m, k), x.dtype)], axis=0)
    mp = m + pad_m
    grid = (mp // block_m, n // block_n)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, block_k=block_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=interpret,
    )(x, w, b.reshape(1, n))
    return out[:m] if pad_m else out


def linear(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none") -> jax.Array:
    """Linear layer over the last axis; leading axes are flattened to rows.

    This is the entry point the L2 model uses: DRCE feeds it a packed
    (tokens, hidden) matrix, the padded path feeds (batch*seq, hidden).
    """
    orig = x.shape
    k = orig[-1]
    rows = 1
    for d in orig[:-1]:
        rows *= d
    y = matmul_bias_act(x.reshape(rows, k), w, b, act=act)
    return y.reshape(orig[:-1] + (w.shape[1],))
