"""Fused layer-normalization Pallas kernel.

The paper (§3.1, Fig. 2) observes that as models grow, GEMM dominates and
memory-bound kernels (layernorm, bias-add, softmax) matter less — but they
still sit on the critical path of every transformer layer, and EnergonAI
keeps them fused per layer. This kernel fuses mean/variance/normalize/
scale/shift into a single pass over each row block.

TPU mapping (DESIGN.md §Hardware-Adaptation): rows are tiled into VMEM via
BlockSpec; each grid step reduces one (block_rows, hidden) tile on the VPU.
``interpret=True`` is mandatory on CPU-PJRT — real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_EPS = 1e-5


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    """One grid step: normalize a (block_rows, hidden) tile."""
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centered = x - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = centered * inv * g_ref[...].astype(jnp.float32) + b_ref[...].astype(
        jnp.float32
    )
    o_ref[...] = y.astype(o_ref.dtype)


def _pick_block(n: int, candidates=(128, 64, 32, 16, 8, 4, 2, 1)) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return 1


def layernorm(
    x: jax.Array,
    gain: jax.Array,
    bias: jax.Array,
    *,
    eps: float = DEFAULT_EPS,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise layernorm over the last axis of ``x``.

    ``x`` may have any leading shape; it is viewed as (rows, hidden).
    ``gain``/``bias`` have shape (hidden,).
    """
    orig_shape = x.shape
    hidden = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, hidden)
    if block_rows is None:
        block_rows = _pick_block(rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, hidden), lambda i: (i, 0)),
            pl.BlockSpec((1, hidden), lambda i: (0, 0)),
            pl.BlockSpec((1, hidden), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        interpret=interpret,
    )(x2, gain.reshape(1, hidden), bias.reshape(1, hidden))
    return out.reshape(orig_shape)
