"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts + manifest.json.

Python runs ONCE at build time (`make artifacts`); the Rust coordinator
loads the HLO text with ``HloModuleProto::from_text_file`` and never
touches Python on the request path.

HLO *text* is the interchange format, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.

Usage:
    python -m compile.aot --out ../artifacts [--plan full|quick]
    python -m compile.aot --out ../artifacts --preset base --kind layer_full \
        --batch 4 --seq 64            # emit one extra variant
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

from . import model as M
from .model import PRESETS, variant


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg, kind, **kw):
    name, fn, args = variant(cfg, kind, **kw)
    specs = [s for _, s in args]
    lowered = jax.jit(fn).lower(*specs)
    out_shapes = jax.eval_shape(fn, *specs)
    entry = {
        "name": name,
        "kind": kind,
        "preset": cfg.name,
        "file": f"{name}.hlo.txt",
        "batch": kw.get("batch", 0),
        "seq": kw.get("seq", 0),
        "tp": kw.get("tp", 1),
        "t_bucket": kw.get("t_bucket", 0),
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": s.dtype.name} for n, s in args
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": o.dtype.name} for o in out_shapes
        ],
    }
    return entry, to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Build plans: which variants the default `make artifacts` emits.
# Shape points are the AOT buckets the dynamic batcher pads into.
# ---------------------------------------------------------------------------

PLANS = {
    "quick": {
        "tiny": {"points": [(2, 16)], "tps": [1, 2], "drce": [(2, 16, 16)]},
    },
    "full": {
        "tiny": {
            "points": [(1, 16), (2, 16), (4, 32)],
            "tps": [1, 2],
            "drce": [(2, 16, 16), (4, 32, 64)],
            # decode bucket widths compiled *independently* of the prefill
            # batch points: wide decode buckets serve many concurrent
            # sessions without widening any prefill bucket
            "decode_widths": [1, 2, 4, 8, 16],
            # speculative decode: verify-window sizes compiled for every
            # decode width (one `*_verify` family per (width, k))
            "spec_ks": [2, 4],
        },
        "small": {
            "points": [(2, 32), (4, 64)],
            "tps": [1, 2, 4],
            "drce": [(4, 64, 128)],
            # 32-wide buckets keep decodes flowing while chunked prefill
            # waves of long prompts interleave through the same queue
            "decode_widths": [2, 4, 8, 16, 32],
            "spec_ks": [2, 4],
        },
        # long-context preset for the decode-latency sweep
        # (scripts/bench_decode.sh: per-token latency vs prefix length)
        "base": {
            "points": [(1, 32), (1, 128)],
            "tps": [1],
            "drce": [],
        },
    },
}


def decode_family_jobs(cfg, width, tps, rows_done):
    """Lowering jobs for one decode bucket width: ``embed_decode`` /
    ``layer_full_decode`` (and per-tp ``attn_shard_decode`` + ``mlp_shard``
    with rows = width) plus a seq=1 ``logits``."""
    jobs = [
        (cfg, "embed_decode", dict(batch=width)),
        (cfg, "layer_full_decode", dict(batch=width)),
        (cfg, "logits", dict(batch=width, seq=1)),
    ]
    for tp in tps:
        jobs.append((cfg, "attn_shard_decode", dict(batch=width, tp=tp)))
        if (tp, width) not in rows_done:
            rows_done.add((tp, width))
            jobs.append((cfg, "mlp_shard", dict(batch=width, seq=1, tp=tp, t_bucket=width)))
    return jobs


def verify_family_jobs(cfg, width, k, tps, rows_done, logits_done):
    """Lowering jobs for one speculative-verify bucket ``(width, k)``:
    ``embed_verify`` / ``layer_full_verify`` (and per-tp
    ``attn_shard_verify`` + ``mlp_shard`` with rows = width*k) plus a
    seq=k ``logits`` head scoring every window row."""
    jobs = [
        (cfg, "embed_verify", dict(batch=width, seq=k)),
        (cfg, "layer_full_verify", dict(batch=width, seq=k)),
    ]
    if (width, k) not in logits_done:
        logits_done.add((width, k))
        jobs.append((cfg, "logits", dict(batch=width, seq=k)))
    for tp in tps:
        jobs.append((cfg, "attn_shard_verify", dict(batch=width, seq=k, tp=tp)))
        if (tp, width * k) not in rows_done:
            rows_done.add((tp, width * k))
            jobs.append(
                (cfg, "mlp_shard", dict(batch=width, seq=k, tp=tp, t_bucket=width * k))
            )
    return jobs


def plan_jobs(plan: dict):
    """Expand a plan into (cfg, kind, kwargs) lowering jobs.

    Every prefill shape point (batch, seq) also gets the incremental-decode
    family for its batch width: ``embed_decode``/``layer_full_decode`` (and
    per-tp ``attn_shard_decode`` + ``mlp_shard`` with rows = batch), a
    seq=1 ``logits``, and the cache-seeding ``layer_full_kv`` /
    ``attn_shard_kv`` prefill twins. A preset's ``decode_widths`` adds
    further decode families *decoupled* from the prefill points, so wide
    decode buckets (e.g. 8/16) exist without an equally wide prefill.
    A preset's ``spec_ks`` additionally emits one speculative-verify
    family per (width, k) over every width compiled above.
    """
    jobs = []
    for preset, spec in plan.items():
        cfg = PRESETS[preset]
        rows_done = set()
        widths_done = set()
        logits_done = set()
        for batch, seq in spec["points"]:
            logits_done.add((batch, seq))
            jobs.append((cfg, "embed", dict(batch=batch, seq=seq)))
            jobs.append((cfg, "layer_full", dict(batch=batch, seq=seq)))
            jobs.append((cfg, "layer_full_kv", dict(batch=batch, seq=seq)))
            jobs.append((cfg, "logits", dict(batch=batch, seq=seq)))
            for tp in spec["tps"]:
                jobs.append((cfg, "attn_shard", dict(batch=batch, seq=seq, tp=tp)))
                jobs.append((cfg, "attn_shard_kv", dict(batch=batch, seq=seq, tp=tp)))
                rows = batch * seq
                if (tp, rows) not in rows_done:
                    rows_done.add((tp, rows))
                    jobs.append((cfg, "mlp_shard", dict(batch=batch, seq=seq, tp=tp)))
            if batch not in widths_done:
                widths_done.add(batch)
                jobs.extend(decode_family_jobs(cfg, batch, spec["tps"], rows_done))
        for width in spec.get("decode_widths", []):
            if width not in widths_done:
                widths_done.add(width)
                jobs.extend(decode_family_jobs(cfg, width, spec["tps"], rows_done))
        # speculative decode: a verify family per (decode width, window k)
        for k in spec.get("spec_ks", []):
            for width in sorted(widths_done):
                jobs.extend(
                    verify_family_jobs(cfg, width, k, spec["tps"], rows_done, logits_done)
                )
        for batch, seq, t in spec.get("drce", []):
            for tp in spec["tps"]:
                jobs.append(
                    (cfg, "drce_attn_shard", dict(batch=batch, seq=seq, tp=tp, t_bucket=t))
                )
                if (tp, t) not in rows_done:
                    rows_done.add((tp, t))
                    jobs.append(
                        (cfg, "mlp_shard", dict(batch=batch, seq=seq, tp=tp, t_bucket=t))
                    )
    return jobs


def write_manifest(out_dir: str, entries: list):
    presets_used = sorted({e["preset"] for e in entries})
    manifest = {
        "format_version": 1,
        "configs": [
            {
                "name": PRESETS[p].name,
                "hidden": PRESETS[p].hidden,
                "n_heads": PRESETS[p].n_heads,
                "head_dim": PRESETS[p].head_dim,
                "ffn": PRESETS[p].ffn,
                "vocab": PRESETS[p].vocab,
                "max_seq": PRESETS[p].max_seq,
                "n_layers": PRESETS[p].n_layers,
            }
            for p in presets_used
        ],
        "variants": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--plan", default="full", choices=list(PLANS) + ["none"])
    ap.add_argument("--preset", help="emit one extra variant for this preset")
    ap.add_argument("--kind", default="layer_full")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--t-bucket", type=int, default=0)
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    jobs = [] if args.plan == "none" else plan_jobs(PLANS[args.plan])
    if args.preset:
        jobs.append(
            (
                PRESETS[args.preset],
                args.kind,
                dict(batch=args.batch, seq=args.seq, tp=args.tp, t_bucket=args.t_bucket),
            )
        )

    entries = []
    t_start = time.time()
    for i, (cfg, kind, kw) in enumerate(jobs):
        t0 = time.time()
        entry, text = lower_variant(cfg, kind, **{k: v for k, v in kw.items() if v})
        with open(os.path.join(args.out, entry["file"]), "w") as f:
            f.write(text)
        entries.append(entry)
        print(
            f"[{i + 1}/{len(jobs)}] {entry['name']}  "
            f"({len(text) / 1024:.0f} KiB, {time.time() - t0:.1f}s)",
            flush=True,
        )

    # merge with any pre-existing manifest entries not re-emitted
    man_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(man_path):
        with open(man_path) as f:
            old = {e["name"]: e for e in json.load(f).get("variants", [])}
        for e in entries:
            old[e["name"]] = e
        entries = [old[k] for k in sorted(old)]
    write_manifest(args.out, entries)
    print(f"wrote {len(entries)} variants + manifest in {time.time() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
