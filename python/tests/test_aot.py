"""AOT pipeline tests: lowering produces loadable HLO text + a manifest
consistent with the variant registry (the Rust loader's contract)."""

import json
import os
import tempfile

import pytest

from compile import aot
from compile import model as M


class TestLowerVariant:
    def test_layer_full_hlo_text(self):
        entry, text = aot.lower_variant(M.PRESETS["tiny"], "layer_full", batch=1, seq=16)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # no TPU custom-calls may leak into CPU artifacts (interpret=True)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
        assert entry["inputs"][0]["shape"] == [1, 16, 64]
        assert entry["outputs"][0]["shape"] == [1, 16, 64]

    def test_input_count_matches_signature(self):
        entry, _ = aot.lower_variant(M.PRESETS["tiny"], "drce_attn_shard", batch=2, seq=16, tp=2, t_bucket=16)
        # x_packed, valid_len, unpad_map, pad_map + 6 attention params
        assert len(entry["inputs"]) == 10
        assert entry["inputs"][1]["dtype"] == "int32"

    def test_dtypes_recorded(self):
        entry, _ = aot.lower_variant(M.PRESETS["tiny"], "embed", batch=2, seq=16)
        assert entry["inputs"][0]["dtype"] == "int32"
        assert entry["inputs"][1]["dtype"] == "float32"


class TestPlans:
    def test_plan_jobs_expand(self):
        jobs = aot.plan_jobs(aot.PLANS["quick"])
        kinds = [k for _, k, _ in jobs]
        for required in ("embed", "layer_full", "logits", "attn_shard", "mlp_shard", "drce_attn_shard"):
            assert required in kinds

    def test_full_plan_covers_tp4(self):
        jobs = aot.plan_jobs(aot.PLANS["full"])
        assert any(kw.get("tp") == 4 for _, _, kw in jobs)

    def test_mlp_rows_not_duplicated(self):
        jobs = aot.plan_jobs(aot.PLANS["full"])
        names = []
        for cfg, kind, kw in jobs:
            if kind == "mlp_shard":
                rows = kw.get("t_bucket") or kw["batch"] * kw["seq"]
                names.append((cfg.name, kw.get("tp", 1), rows))
        assert len(names) == len(set(names))

    def test_decode_widths_decoupled_from_prefill_points(self):
        # widths 8/16 exist on tiny with no (8, s) or (16, s) prefill point
        jobs = aot.plan_jobs(aot.PLANS["full"])
        tiny = [(k, kw) for cfg, k, kw in jobs if cfg.name == "tiny"]
        widths = sorted(kw["batch"] for k, kw in tiny if k == "layer_full_decode")
        assert widths == aot.PLANS["full"]["tiny"]["decode_widths"]
        prefill_batches = {kw["batch"] for k, kw in tiny if k == "layer_full"}
        assert not {8, 16} & prefill_batches
        # every extra width carries its full family: embed_decode, seq-1
        # logits, per-tp attn_shard_decode and rows=width mlp_shard
        for w in (8, 16):
            assert any(k == "embed_decode" and kw["batch"] == w for k, kw in tiny)
            assert any(k == "logits" and kw["batch"] == w and kw["seq"] == 1 for k, kw in tiny)
            for tp in aot.PLANS["full"]["tiny"]["tps"]:
                assert any(
                    k == "attn_shard_decode" and kw["batch"] == w and kw["tp"] == tp
                    for k, kw in tiny
                )
            # rows=w mlp_shard exists (possibly shared with a prefill
            # point of the same row count — variant names key on rows)
            assert any(
                k == "mlp_shard" and (kw.get("t_bucket") or kw["batch"] * kw["seq"]) == w
                for k, kw in tiny
            )


    def test_small_family_compiles_wide_decode_for_chunked_prefill(self):
        # the 32-wide decode bucket exists on small (no 32-wide prefill
        # point), with its whole family — decodes stay wide while chunk
        # waves of long prompts interleave through the same queue
        jobs = aot.plan_jobs(aot.PLANS["full"])
        small = [(k, kw) for cfg, k, kw in jobs if cfg.name == "small"]
        widths = sorted(kw["batch"] for k, kw in small if k == "layer_full_decode")
        assert widths == aot.PLANS["full"]["small"]["decode_widths"]
        assert 32 in widths
        prefill_batches = {kw["batch"] for k, kw in small if k == "layer_full"}
        assert 32 not in prefill_batches
        assert any(k == "embed_decode" and kw["batch"] == 32 for k, kw in small)
        assert any(k == "logits" and kw["batch"] == 32 and kw["seq"] == 1 for k, kw in small)
        for tp in aot.PLANS["full"]["small"]["tps"]:
            assert any(
                k == "attn_shard_decode" and kw["batch"] == 32 and kw["tp"] == tp
                for k, kw in small
            )
        # the verify families (chunked prefill's chunk-window kernels)
        # extend over the new width too
        for spec_k in aot.PLANS["full"]["small"]["spec_ks"]:
            assert any(
                k == "embed_verify" and kw["batch"] == 32 and kw["seq"] == spec_k
                for k, kw in small
            ), spec_k

    def test_spec_ks_emit_whole_verify_families(self):
        # every (width, k) pair carries embed_verify, layer_full_verify,
        # a seq=k logits head, per-tp attn_shard_verify and a rows=w*k
        # mlp_shard (possibly shared with another point of the same rows)
        jobs = aot.plan_jobs(aot.PLANS["full"])
        tiny = [(k, kw) for cfg, k, kw in jobs if cfg.name == "tiny"]
        widths = sorted(kw["batch"] for k, kw in tiny if k == "layer_full_decode")
        for w in widths:
            for spec_k in aot.PLANS["full"]["tiny"]["spec_ks"]:
                assert any(
                    k == "embed_verify" and kw["batch"] == w and kw["seq"] == spec_k
                    for k, kw in tiny
                ), (w, spec_k)
                assert any(
                    k == "layer_full_verify" and kw["batch"] == w and kw["seq"] == spec_k
                    for k, kw in tiny
                )
                assert any(
                    k == "logits" and kw["batch"] == w and kw["seq"] == spec_k
                    for k, kw in tiny
                )
                for tp in aot.PLANS["full"]["tiny"]["tps"]:
                    assert any(
                        k == "attn_shard_verify"
                        and kw["batch"] == w and kw["seq"] == spec_k and kw["tp"] == tp
                        for k, kw in tiny
                    )
                assert any(
                    k == "mlp_shard"
                    and (kw.get("t_bucket") or kw["batch"] * kw["seq"]) == w * spec_k
                    for k, kw in tiny
                )


class TestEndToEnd:
    def test_quick_plan_writes_manifest(self, tmp_path):
        rc = aot.main(["--out", str(tmp_path), "--plan", "quick"])
        assert rc == 0
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["format_version"] == 1
        assert man["configs"][0]["name"] == "tiny"
        for v in man["variants"]:
            assert (tmp_path / v["file"]).exists()

    def test_manifest_merge_keeps_old_entries(self, tmp_path):
        aot.main(["--out", str(tmp_path), "--plan", "quick"])
        n0 = len(json.loads((tmp_path / "manifest.json").read_text())["variants"])
        aot.main(
            ["--out", str(tmp_path), "--plan", "none", "--preset", "tiny",
             "--kind", "layer_full", "--batch", "1", "--seq", "16"]
        )
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert len(man["variants"]) == n0 + 1
