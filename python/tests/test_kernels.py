"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Fixed-shape unit tests cover the geometries the AOT plan actually emits;
hypothesis sweeps shapes/dtypes beyond them (deadline disabled — interpret
mode is slow on 1 CPU core).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    attention,
    gather_rows,
    layernorm,
    linear,
    make_maps,
    matmul_bias_act,
    rebuild_padding,
    remove_padding,
)
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=15, print_blob=True)


def rng(*keys):
    return jax.random.split(jax.random.PRNGKey(0), len(keys))


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

class TestLayerNorm:
    @pytest.mark.parametrize("rows,hidden", [(4, 64), (32, 256), (7, 64), (1, 8)])
    def test_matches_ref(self, rows, hidden):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        x = jax.random.normal(k1, (rows, hidden), jnp.float32)
        g = jax.random.normal(k2, (hidden,)) * 0.1 + 1.0
        b = jax.random.normal(k3, (hidden,)) * 0.1
        assert_allclose(layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5)

    def test_3d_input(self):
        k = jax.random.PRNGKey(2)
        x = jax.random.normal(k, (2, 8, 32))
        g = jnp.ones(32)
        b = jnp.zeros(32)
        out = layernorm(x, g, b)
        assert out.shape == (2, 8, 32)
        assert_allclose(out, ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5)

    def test_rows_not_multiple_of_large_block(self):
        # 6 rows forces block selection down to 2
        x = jax.random.normal(jax.random.PRNGKey(3), (6, 16))
        out = layernorm(x, jnp.ones(16), jnp.zeros(16))
        assert_allclose(out, ref.layernorm_ref(x, jnp.ones(16), jnp.zeros(16)), rtol=2e-5, atol=2e-5)

    def test_constant_rows_are_finite(self):
        x = jnp.ones((4, 16))
        out = layernorm(x, jnp.ones(16), jnp.zeros(16))
        assert np.all(np.isfinite(np.asarray(out)))

    @given(
        rows=st.integers(1, 48),
        hidden=st.sampled_from([8, 16, 32, 64, 128]),
        dtype=st.sampled_from(["float32", "bfloat16"]),
    )
    @settings(**SETTINGS)
    def test_hypothesis_shapes(self, rows, hidden, dtype):
        dt = jnp.dtype(dtype)
        x = jax.random.normal(jax.random.PRNGKey(rows * hidden), (rows, hidden)).astype(dt)
        g = jnp.ones(hidden, dt)
        b = jnp.zeros(hidden, dt)
        tol = 2e-5 if dtype == "float32" else 5e-2
        assert_allclose(
            np.asarray(layernorm(x, g, b), np.float32),
            np.asarray(ref.layernorm_ref(x, g, b), np.float32),
            rtol=tol,
            atol=tol,
        )


# ---------------------------------------------------------------------------
# fused matmul + bias + act
# ---------------------------------------------------------------------------

class TestMatmul:
    @pytest.mark.parametrize("act", ["none", "gelu", "relu"])
    def test_acts(self, act):
        k1, k2 = jax.random.split(jax.random.PRNGKey(4))
        x = jax.random.normal(k1, (16, 64))
        w = jax.random.normal(k2, (64, 32)) / 8.0
        b = jnp.linspace(-1, 1, 32)
        assert_allclose(
            matmul_bias_act(x, w, b, act=act),
            ref.matmul_bias_act_ref(x, w, b, act=act),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_m_not_block_aligned(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (13, 32))
        w = jax.random.normal(jax.random.PRNGKey(6), (32, 16))
        b = jnp.zeros(16)
        out = matmul_bias_act(x, w, b)
        assert out.shape == (13, 16)
        assert_allclose(out, ref.matmul_bias_act_ref(x, w, b), rtol=1e-4, atol=1e-4)

    def test_linear_3d(self):
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 32))
        w = jax.random.normal(jax.random.PRNGKey(8), (32, 64)) / 4
        b = jnp.ones(64)
        out = linear(x, w, b, act="gelu")
        assert out.shape == (2, 8, 64)
        assert_allclose(out, ref.linear_ref(x, w, b, "gelu"), rtol=1e-4, atol=1e-4)

    def test_explicit_blocks(self):
        x = jax.random.normal(jax.random.PRNGKey(9), (32, 128))
        w = jax.random.normal(jax.random.PRNGKey(10), (128, 64)) / 8
        b = jnp.zeros(64)
        out = matmul_bias_act(x, w, b, block_m=8, block_n=16, block_k=32)
        assert_allclose(out, ref.matmul_bias_act_ref(x, w, b), rtol=1e-4, atol=1e-4)

    @given(
        m=st.integers(1, 40),
        k=st.sampled_from([16, 32, 64, 128]),
        n=st.sampled_from([16, 32, 64]),
        act=st.sampled_from(["none", "gelu"]),
        dtype=st.sampled_from(["float32", "bfloat16"]),
    )
    @settings(**SETTINGS)
    def test_hypothesis_shapes(self, m, k, n, act, dtype):
        dt = jnp.dtype(dtype)
        kx, kw = jax.random.split(jax.random.PRNGKey(m * k + n))
        x = (jax.random.normal(kx, (m, k)) / 4).astype(dt)
        w = (jax.random.normal(kw, (k, n)) / 4).astype(dt)
        b = jnp.zeros(n, dt)
        tol = 1e-4 if dtype == "float32" else 8e-2
        assert_allclose(
            np.asarray(matmul_bias_act(x, w, b, act=act), np.float32),
            np.asarray(ref.matmul_bias_act_ref(x, w, b, act=act), np.float32),
            rtol=tol,
            atol=tol,
        )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _qkvb(key, batch, heads, seq, hd, valid=None):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (batch, heads, seq, hd))
    k = jax.random.normal(k2, (batch, heads, seq, hd))
    v = jax.random.normal(k3, (batch, heads, seq, hd))
    if valid is None:
        valid = jnp.full((batch,), seq, jnp.int32)
    bias = ref.causal_padding_bias(valid, seq)
    return q, k, v, bias


class TestAttention:
    @pytest.mark.parametrize("batch,heads,seq,hd", [(1, 1, 16, 8), (2, 4, 32, 16), (2, 2, 64, 32)])
    def test_causal_matches_ref(self, batch, heads, seq, hd):
        q, k, v, bias = _qkvb(jax.random.PRNGKey(11), batch, heads, seq, hd)
        assert_allclose(
            attention(q, k, v, bias), ref.attention_ref(q, k, v, bias), rtol=2e-4, atol=2e-4
        )

    def test_padding_mask(self):
        valid = jnp.array([3, 16], jnp.int32)
        q, k, v, bias = _qkvb(jax.random.PRNGKey(12), 2, 2, 16, 8, valid)
        out = attention(q, k, v, bias)
        expect = ref.attention_ref(q, k, v, bias)
        # valid region matches
        assert_allclose(out[0, :, :3], expect[0, :, :3], rtol=2e-4, atol=2e-4)
        assert_allclose(out[1], expect[1], rtol=2e-4, atol=2e-4)
        # fully padded query rows are finite (NEG_INF, not -inf)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_block_sizes(self):
        q, k, v, bias = _qkvb(jax.random.PRNGKey(13), 1, 2, 32, 16)
        for bq, bk in [(8, 8), (16, 32), (32, 4)]:
            out = attention(q, k, v, bias, block_q=bq, block_k=bk)
            assert_allclose(out, ref.attention_ref(q, k, v, bias), rtol=2e-4, atol=2e-4)

    def test_first_token_attends_only_self(self):
        q, k, v, bias = _qkvb(jax.random.PRNGKey(14), 1, 1, 16, 8)
        out = attention(q, k, v, bias)
        assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=2e-4, atol=2e-4)

    @given(
        batch=st.integers(1, 3),
        heads=st.sampled_from([1, 2, 4]),
        seq=st.sampled_from([8, 16, 32]),
        hd=st.sampled_from([8, 16, 32]),
    )
    @settings(**SETTINGS)
    def test_hypothesis_shapes(self, batch, heads, seq, hd):
        valid = jnp.arange(1, batch + 1, dtype=jnp.int32) * (seq // (batch + 1)) + 1
        q, k, v, bias = _qkvb(jax.random.PRNGKey(seq * hd + batch), batch, heads, seq, hd, valid)
        assert_allclose(
            attention(q, k, v, bias), ref.attention_ref(q, k, v, bias), rtol=3e-4, atol=3e-4
        )


# ---------------------------------------------------------------------------
# DRCE pack/unpack
# ---------------------------------------------------------------------------

class TestPack:
    def test_gather_rows(self):
        src = jax.random.normal(jax.random.PRNGKey(15), (10, 8))
        idx = jnp.array([0, 3, 3, 9, 1, 2, 5, 7], jnp.int32)
        assert_allclose(gather_rows(src, idx), ref.gather_rows_ref(src, idx))

    def test_roundtrip(self):
        batch, seq, h = 3, 8, 16
        valid = [5, 8, 2]
        unpad, pad, total = make_maps(valid, seq, t_bucket=16)
        assert total == 15
        x = jax.random.normal(jax.random.PRNGKey(16), (batch * seq, h))
        packed = remove_padding(x, jnp.asarray(unpad))
        rebuilt = rebuild_padding(packed[:total].reshape(total, h), jnp.asarray(pad))
        rebuilt = np.asarray(rebuilt).reshape(batch, seq, h)
        xr = np.asarray(x).reshape(batch, seq, h)
        for b, vl in enumerate(valid):
            assert_allclose(rebuilt[b, :vl], xr[b, :vl])
            assert_allclose(rebuilt[b, vl:], 0.0)

    def test_bucket_overflow_raises(self):
        with pytest.raises(ValueError):
            make_maps([8, 8], 8, t_bucket=15)

    def test_slack_rows_replicate_row0(self):
        unpad, pad, total = make_maps([2], 8, t_bucket=8)
        assert total == 2
        assert list(unpad[total:]) == [0] * 6
        # pad map never references slack rows
        assert all(p == 8 or p < total for p in pad)

    @given(
        seq=st.sampled_from([8, 16]),
        lens=st.lists(st.integers(1, 8), min_size=1, max_size=4),
    )
    @settings(**SETTINGS)
    def test_hypothesis_roundtrip(self, seq, lens):
        lens = [min(l, seq) for l in lens]
        total = sum(lens)
        bucket = ((total + 7) // 8) * 8
        unpad, pad, t = make_maps(lens, seq, bucket)
        h = 4
        x = jnp.arange(len(lens) * seq * h, dtype=jnp.float32).reshape(len(lens) * seq, h)
        packed = remove_padding(x, jnp.asarray(unpad))
        rebuilt = np.asarray(rebuild_padding(packed, jnp.asarray(pad)))
        xr = np.asarray(x).reshape(len(lens), seq, h)
        rb = rebuilt.reshape(len(lens), seq, h)
        for b, vl in enumerate(lens):
            assert_allclose(rb[b, :vl], xr[b, :vl])
            assert_allclose(rb[b, vl:], 0.0)
