"""L2 model correctness: variant builders vs the pure-jnp layer oracle.

The critical invariants:
  * layer_full == layer_ref (kernels compose correctly),
  * TP shards + all-reduce + host residual adds == layer_full for every tp
    (the coordinator's reassembly contract),
  * DRCE packed path == padded path on the valid region (§4.3),
  * embed/logits match their oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels import make_maps, remove_padding
from compile.kernels import ref

TINY = M.PRESETS["tiny"]


def make_layer_params(key, cfg):
    ks = jax.random.split(key, 12)
    spec = M.layer_param_spec(cfg, tp=1)
    params = {}
    for (name, shape), k in zip(spec, ks):
        if name.endswith("_g"):
            params[name] = jnp.ones(shape) + jax.random.normal(k, shape) * 0.02
        elif name.startswith("w"):
            fan_in = shape[0]
            params[name] = jax.random.normal(k, shape) / np.sqrt(fan_in)
        else:
            params[name] = jax.random.normal(k, shape) * 0.02
    return params


def param_list(params, names):
    return [params[n] for n in names]


ALL = M.ATTN_PARAMS + M.MLP_PARAMS


class TestLayerFull:
    @pytest.mark.parametrize("batch,seq", [(1, 16), (2, 16)])
    def test_matches_oracle(self, batch, seq):
        params = make_layer_params(jax.random.PRNGKey(0), TINY)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, TINY.hidden))
        valid = jnp.full((batch,), seq, jnp.int32)
        fn = M.build_layer_full(TINY)
        (y,) = fn(x, valid, *param_list(params, ALL))
        expect = ref.layer_ref(x, valid, params, TINY.n_heads)
        assert_allclose(np.asarray(y), np.asarray(expect), rtol=5e-4, atol=5e-4)

    def test_variable_lengths_valid_region(self):
        params = make_layer_params(jax.random.PRNGKey(2), TINY)
        batch, seq = 2, 16
        x = jax.random.normal(jax.random.PRNGKey(3), (batch, seq, TINY.hidden))
        valid = jnp.array([5, 12], jnp.int32)
        fn = M.build_layer_full(TINY)
        (y,) = fn(x, valid, *param_list(params, ALL))
        expect = ref.layer_ref(x, valid, params, TINY.n_heads)
        for b, vl in enumerate([5, 12]):
            assert_allclose(
                np.asarray(y)[b, :vl], np.asarray(expect)[b, :vl], rtol=5e-4, atol=5e-4
            )

    def test_jit_lowers(self):
        # the exact path aot.py takes must trace without concrete inputs
        name, fn, args = M.variant(TINY, "layer_full", batch=1, seq=16)
        jax.jit(fn).lower(*[s for _, s in args])


class TestTensorParallel:
    @pytest.mark.parametrize("tp", [1, 2])
    def test_shards_reassemble_to_full_layer(self, tp):
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(4), cfg)
        batch, seq = 2, 16
        x = jax.random.normal(jax.random.PRNGKey(5), (batch, seq, cfg.hidden))
        valid = jnp.array([16, 9], jnp.int32)

        full = M.build_layer_full(cfg)
        (expect,) = full(x, valid, *param_list(params, ALL))

        attn_fn = M.build_attn_shard(cfg, tp)
        mlp_fn = M.build_mlp_shard(cfg, tp)
        shards = [M.shard_layer_params(params, tp, r, cfg.n_heads) for r in range(tp)]

        # coordinator contract: all-reduce partials, residual adds on host
        attn_sum = sum(
            attn_fn(x, valid, *param_list(s, M.ATTN_PARAMS))[0] for s in shards
        )
        r = x + attn_sum
        r2 = r.reshape(batch * seq, cfg.hidden)
        mlp_sum = sum(mlp_fn(r2, *param_list(s, M.MLP_PARAMS))[0] for s in shards)
        y = r + mlp_sum.reshape(batch, seq, cfg.hidden)
        assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-3, atol=1e-3)

    def test_shard_param_shapes_match_spec(self):
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(6), cfg)
        for tp in (1, 2):
            spec = dict(M.layer_param_spec(cfg, tp))
            for r in range(tp):
                s = M.shard_layer_params(params, tp, r, cfg.n_heads)
                for name, shape in spec.items():
                    assert s[name].shape == shape, (tp, r, name)

    def test_row_bias_divided(self):
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(7), cfg)
        s0 = M.shard_layer_params(params, 2, 0, cfg.n_heads)
        s1 = M.shard_layer_params(params, 2, 1, cfg.n_heads)
        assert_allclose(np.asarray(s0["bo"] + s1["bo"]), np.asarray(params["bo"]), rtol=1e-6)
        assert_allclose(np.asarray(s0["b2"] + s1["b2"]), np.asarray(params["b2"]), rtol=1e-6)


class TestDRCE:
    @pytest.mark.parametrize("tp", [1, 2])
    def test_packed_equals_padded(self, tp):
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(8), cfg)
        batch, seq = 2, 16
        lens = [9, 7]
        t_bucket = 16
        unpad, pad, total = make_maps(lens, seq, t_bucket)
        x = jax.random.normal(jax.random.PRNGKey(9), (batch, seq, cfg.hidden))
        # zero the pad region like the batcher does (pad rows never affect
        # valid outputs either way, but packed slack rows replicate row 0)
        mask = (jnp.arange(seq)[None, :] < jnp.asarray(lens)[:, None])[..., None]
        x = x * mask
        valid = jnp.asarray(lens, jnp.int32)

        full = M.build_layer_full(cfg)
        (expect,) = full(x, valid, *param_list(params, ALL))

        x_packed = remove_padding(x.reshape(batch * seq, cfg.hidden), jnp.asarray(unpad))
        drce_fn = M.build_drce_attn_shard(cfg, tp, batch, seq, t_bucket)
        mlp_fn = M.build_mlp_shard(cfg, tp)
        shards = [M.shard_layer_params(params, tp, r, cfg.n_heads) for r in range(tp)]

        attn_sum = sum(
            drce_fn(
                x_packed,
                valid,
                jnp.asarray(unpad),
                jnp.asarray(pad),
                *param_list(s, M.ATTN_PARAMS),
            )[0]
            for s in shards
        )
        r_packed = x_packed + attn_sum
        mlp_sum = sum(mlp_fn(r_packed, *param_list(s, M.MLP_PARAMS))[0] for s in shards)
        y_packed = np.asarray(r_packed + mlp_sum)

        ex = np.asarray(expect).reshape(batch * seq, cfg.hidden)
        for j in range(total):
            assert_allclose(y_packed[j], ex[unpad[j]], rtol=2e-3, atol=2e-3)

    def test_flop_savings_ratio(self):
        # paper setup: valid = pad/2 -> linears see half the rows
        seq = 16
        lens = [seq // 2] * 4
        unpad, pad, total = make_maps(lens, seq, t_bucket=32)
        assert total == 2 * seq  # half of 4*16


class TestEmbedLogits:
    def test_embed(self):
        cfg = TINY
        ids = jnp.array([[1, 5, 7, 0] * 4, [2, 2, 3, 9] * 4], jnp.int32)
        wte = jax.random.normal(jax.random.PRNGKey(10), (cfg.vocab, cfg.hidden))
        wpe = jax.random.normal(jax.random.PRNGKey(11), (cfg.max_seq, cfg.hidden))
        (y,) = M.build_embed(cfg)(ids, wte, wpe)
        assert_allclose(np.asarray(y), np.asarray(ref.embed_ref(ids, wte, wpe)), rtol=1e-6)

    def test_logits(self):
        cfg = TINY
        x = jax.random.normal(jax.random.PRNGKey(12), (2, 16, cfg.hidden))
        g, b = jnp.ones(cfg.hidden), jnp.zeros(cfg.hidden)
        wte = jax.random.normal(jax.random.PRNGKey(13), (cfg.vocab, cfg.hidden))
        (z,) = M.build_logits(cfg)(x, g, b, wte)
        assert z.shape == (2, 16, cfg.vocab)
        assert_allclose(
            np.asarray(z), np.asarray(ref.logits_ref(x, g, b, wte)), rtol=5e-4, atol=5e-4
        )


class TestDecode:
    """Incremental-decode variants: cached single-position execution must
    reproduce the full-prefix padded path exactly (the Rust differential
    test `rust/tests/kv_decode.rs` pins the same invariant end to end)."""

    def _prefix_kv(self, x, valid, params):
        """Oracle K/V of the padded prefix (what the cache would hold)."""
        a = ref.layernorm_ref(x, params["ln1_g"], params["ln1_b"])
        qkv = ref.linear_ref(a, params["wqkv"], params["bqkv"])
        _, k, v = jnp.split(qkv, 3, axis=-1)
        return k, v

    def test_kv_outputs_match_oracle_and_y_matches_layer_full(self):
        params = make_layer_params(jax.random.PRNGKey(20), TINY)
        batch, seq = 2, 16
        x = jax.random.normal(jax.random.PRNGKey(21), (batch, seq, TINY.hidden))
        valid = jnp.array([seq, 9], jnp.int32)
        (y_full,) = M.build_layer_full(TINY)(x, valid, *param_list(params, ALL))
        y_kv, k, v = M.build_layer_full_kv(TINY)(x, valid, *param_list(params, ALL))
        assert_allclose(np.asarray(y_kv), np.asarray(y_full), rtol=1e-5, atol=1e-5)
        k_ref, v_ref = self._prefix_kv(x, valid, params)
        assert_allclose(np.asarray(k), np.asarray(k_ref), rtol=5e-4, atol=5e-4)
        assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=5e-4, atol=5e-4)

    @pytest.mark.parametrize("lens", [[16, 9], [5, 12]])
    def test_decode_step_matches_full_layer_last_position(self, lens):
        """Running position L-1 through layer_full_decode with the prefix
        cache must equal row L-1 of layer_full over the whole sequence."""
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(22), cfg)
        batch, seq = len(lens), 16
        x = jax.random.normal(jax.random.PRNGKey(23), (batch, seq, cfg.hidden))
        valid = jnp.asarray(lens, jnp.int32)
        (expect,) = M.build_layer_full(cfg)(x, valid, *param_list(params, ALL))

        # cache = oracle K/V of positions 0..L-2; staging is zero elsewhere
        k_all, v_all = self._prefix_kv(x, valid, params)
        prefix = jnp.arange(seq)[None, :, None] < (valid[:, None, None] - 1)
        k_cache = jnp.where(prefix, k_all, 0.0)
        # pad the cache out to max_seq like the Rust staging buffer does
        padw = cfg.max_seq - seq
        k_cache = jnp.pad(k_cache, ((0, 0), (0, padw), (0, 0)))
        v_cache = jnp.pad(jnp.where(prefix, v_all, 0.0), ((0, 0), (0, padw), (0, 0)))

        x_last = jnp.stack([x[b, l - 1] for b, l in enumerate(lens)])[:, None, :]
        y, k_new, v_new = M.build_layer_full_decode(cfg)(
            x_last, valid, k_cache, v_cache, *param_list(params, ALL)
        )
        for b, l in enumerate(lens):
            assert_allclose(
                np.asarray(y)[b, 0], np.asarray(expect)[b, l - 1], rtol=2e-3, atol=2e-3
            )
            assert_allclose(
                np.asarray(k_new)[b, 0], np.asarray(k_all)[b, l - 1], rtol=1e-3, atol=1e-3
            )
            assert_allclose(
                np.asarray(v_new)[b, 0], np.asarray(v_all)[b, l - 1], rtol=1e-3, atol=1e-3
            )

    @pytest.mark.parametrize("tp", [1, 2])
    def test_attn_shard_decode_reassembles(self, tp):
        """TP decode shards + all-reduce + host residual + mlp_shard(rows=B)
        must equal layer_full_decode — the coordinator's decode contract."""
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(24), cfg)
        batch, seq = 2, cfg.max_seq
        lens = [7, 13]
        valid = jnp.asarray(lens, jnp.int32)
        x_last = jax.random.normal(jax.random.PRNGKey(25), (batch, 1, cfg.hidden))
        k_all = jax.random.normal(jax.random.PRNGKey(26), (batch, seq, cfg.hidden)) * 0.5
        v_all = jax.random.normal(jax.random.PRNGKey(27), (batch, seq, cfg.hidden)) * 0.5
        prefix = jnp.arange(seq)[None, :, None] < (valid[:, None, None] - 1)
        k_cache = jnp.where(prefix, k_all, 0.0)
        v_cache = jnp.where(prefix, v_all, 0.0)

        expect, k_ref, v_ref = M.build_layer_full_decode(cfg)(
            x_last, valid, k_cache, v_cache, *param_list(params, ALL)
        )

        hd = cfg.head_dim
        heads_local = cfg.n_heads // tp
        w = heads_local * hd
        shards = [M.shard_layer_params(params, tp, r, cfg.n_heads) for r in range(tp)]
        decode_fn = M.build_attn_shard_decode(cfg, tp)
        mlp_fn = M.build_mlp_shard(cfg, tp)
        # head-group column shard of the cache, mirroring shard_layer_params
        parts = []
        for r, s in enumerate(shards):
            sl = slice(r * w, (r + 1) * w)
            parts.append(
                decode_fn(
                    x_last, valid, k_cache[..., sl], v_cache[..., sl],
                    *param_list(s, M.ATTN_PARAMS),
                )
            )
        attn_sum = sum(p[0] for p in parts)
        r_res = x_last + attn_sum
        r2 = r_res.reshape(batch, cfg.hidden)
        mlp_sum = sum(mlp_fn(r2, *param_list(s, M.MLP_PARAMS))[0] for s in shards)
        y = r_res + mlp_sum.reshape(batch, 1, cfg.hidden)
        assert_allclose(np.asarray(y), np.asarray(expect), rtol=2e-3, atol=2e-3)
        # shard K/V rows concatenate to the full new row
        k_cat = jnp.concatenate([p[1] for p in parts], axis=-1)
        v_cat = jnp.concatenate([p[2] for p in parts], axis=-1)
        assert_allclose(np.asarray(k_cat), np.asarray(k_ref), rtol=1e-3, atol=1e-3)
        assert_allclose(np.asarray(v_cat), np.asarray(v_ref), rtol=1e-3, atol=1e-3)

    def test_embed_decode_matches_embed_position(self):
        cfg = TINY
        ids = jnp.array([[1, 5, 7, 9], [2, 2, 3, 4]], jnp.int32)
        wte = jax.random.normal(jax.random.PRNGKey(28), (cfg.vocab, cfg.hidden))
        wpe = jax.random.normal(jax.random.PRNGKey(29), (cfg.max_seq, cfg.hidden))
        (full,) = M.build_embed(cfg)(ids, wte, wpe)
        pos = jnp.array([3, 1], jnp.int32)
        last_ids = jnp.stack([ids[b, p] for b, p in enumerate([3, 1])])[:, None]
        (y,) = M.build_embed_decode(cfg)(last_ids, pos, wte, wpe)
        for b, p in enumerate([3, 1]):
            assert_allclose(np.asarray(y)[b, 0], np.asarray(full)[b, p], rtol=1e-6)

    def test_incremental_generation_matches_full_prefix(self):
        """Token-by-token decode through the cache reproduces the full
        padded forward at every step — the O(N·(P+N)) → O(P+N) claim is
        only valid because of this invariant."""
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(30), cfg)
        seq = 10
        x = jax.random.normal(jax.random.PRNGKey(31), (1, seq, cfg.hidden))
        kv_fn = M.build_layer_full_kv(cfg)
        dec_fn = M.build_layer_full_decode(cfg)

        # prefill positions 0..4 through the kv twin
        p_len = 5
        valid_p = jnp.array([p_len], jnp.int32)
        xp = jnp.pad(x[:, :p_len], ((0, 0), (0, cfg.max_seq - p_len), (0, 0)))
        _, k, v = kv_fn(xp, valid_p, *param_list(params, ALL))
        k_cache = jnp.where(jnp.arange(cfg.max_seq)[None, :, None] < p_len, k, 0.0)
        v_cache = jnp.where(jnp.arange(cfg.max_seq)[None, :, None] < p_len, v, 0.0)

        for l in range(p_len + 1, seq + 1):
            valid = jnp.array([l], jnp.int32)
            y, k_new, v_new = dec_fn(
                x[:, l - 1 : l], valid, k_cache, v_cache, *param_list(params, ALL)
            )
            (expect,) = M.build_layer_full(cfg)(
                jnp.pad(x[:, :l], ((0, 0), (0, cfg.max_seq - l), (0, 0))),
                valid,
                *param_list(params, ALL),
            )
            assert_allclose(
                np.asarray(y)[0, 0], np.asarray(expect)[0, l - 1], rtol=2e-3, atol=2e-3
            )
            onehot = (jnp.arange(cfg.max_seq) == l - 1)[None, :, None]
            k_cache = jnp.where(onehot, k_new, k_cache)
            v_cache = jnp.where(onehot, v_new, v_cache)

    def test_decode_variants_lower(self):
        # the exact path aot.py takes must trace without concrete inputs
        for kind, kw in [
            ("embed_decode", dict(batch=2)),
            ("layer_full_decode", dict(batch=2)),
            ("attn_shard_decode", dict(batch=2, tp=2)),
            ("layer_full_kv", dict(batch=2, seq=16)),
            ("attn_shard_kv", dict(batch=2, seq=16, tp=2)),
        ]:
            name, fn, args = M.variant(TINY, kind, **kw)
            jax.jit(fn).lower(*[s for _, s in args])


class TestVerify:
    """Speculative-decode verify variants: row j of a (B, K) candidate
    window must equal a plain decode step at position base+j for every
    j < K — the per-row equivalence that makes draft-and-verify lossless
    under greedy sampling (the Rust differential suite
    `rust/tests/spec_decode.rs` pins the same invariant end to end)."""

    def _prefix_kv(self, x, params):
        """Oracle K/V rows of the padded input (what the cache holds)."""
        a = ref.layernorm_ref(x, params["ln1_g"], params["ln1_b"])
        qkv = ref.linear_ref(a, params["wqkv"], params["bqkv"])
        _, k, v = jnp.split(qkv, 3, axis=-1)
        return k, v

    @pytest.mark.parametrize("k_win", [2, 4])
    def test_verify_rows_match_sequential_decode(self, k_win):
        """One verify pass over a K-window == K sequential decode steps
        feeding each new K/V row back into the cache."""
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(40), cfg)
        batch, s = 2, cfg.max_seq
        lens = [9, 6]  # total tokens *including* the window
        valid = jnp.asarray(lens, jnp.int32)
        base = valid - k_win
        x_win = jax.random.normal(jax.random.PRNGKey(41), (batch, k_win, cfg.hidden))
        k_all = jax.random.normal(jax.random.PRNGKey(42), (batch, s, cfg.hidden)) * 0.5
        v_all = jax.random.normal(jax.random.PRNGKey(43), (batch, s, cfg.hidden)) * 0.5
        prefix = jnp.arange(s)[None, :, None] < base[:, None, None]
        k_cache = jnp.where(prefix, k_all, 0.0)
        v_cache = jnp.where(prefix, v_all, 0.0)

        y, k_new, v_new = M.build_layer_full_verify(cfg)(
            x_win, valid, k_cache, v_cache, *param_list(params, ALL)
        )
        assert y.shape == (batch, k_win, cfg.hidden)
        assert k_new.shape == (batch, k_win, cfg.hidden)

        # oracle: run the window one position at a time through the plain
        # decode variant, appending each step's K/V row before the next
        dec = M.build_layer_full_decode(cfg)
        kc, vc = k_cache, v_cache
        for j in range(k_win):
            vl = base + j + 1  # tokens incl the one being decoded
            yj, kj, vj = dec(x_win[:, j : j + 1], vl, kc, vc, *param_list(params, ALL))
            assert_allclose(
                np.asarray(y)[:, j], np.asarray(yj)[:, 0], rtol=2e-3, atol=2e-3,
                err_msg=f"window row {j} diverged from the decode step",
            )
            assert_allclose(np.asarray(k_new)[:, j], np.asarray(kj)[:, 0], rtol=1e-3, atol=1e-3)
            assert_allclose(np.asarray(v_new)[:, j], np.asarray(vj)[:, 0], rtol=1e-3, atol=1e-3)
            onehot = (jnp.arange(s)[None, :] == (base + j)[:, None])[:, :, None]
            kc = jnp.where(onehot, kj, kc)
            vc = jnp.where(onehot, vj, vc)

    def test_verify_rows_match_ref_layer(self):
        """Window rows also match the pure-ref full-prefix layer at the
        corresponding positions (per-row causal masking is correct)."""
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(44), cfg)
        k_win, total = 3, 11
        s = cfg.max_seq
        x = jax.random.normal(jax.random.PRNGKey(45), (1, s, cfg.hidden))
        x = x * (jnp.arange(s)[None, :, None] < total)
        base = total - k_win
        k_all, v_all = self._prefix_kv(x, params)
        keep = jnp.arange(s)[None, :, None] < base
        y, k_new, v_new = M.build_layer_full_verify(cfg)(
            x[:, base:total],
            jnp.array([total], jnp.int32),
            jnp.where(keep, k_all, 0.0),
            jnp.where(keep, v_all, 0.0),
            *param_list(params, ALL),
        )
        for j in range(k_win):
            vl = jnp.array([base + j + 1], jnp.int32)
            expect = ref.layer_ref(x, vl, params, cfg.n_heads)
            assert_allclose(
                np.asarray(y)[0, j], np.asarray(expect)[0, base + j], rtol=2e-3, atol=2e-3,
                err_msg=f"window row {j} diverged from the ref layer",
            )
            assert_allclose(np.asarray(k_new)[0, j], np.asarray(k_all)[0, base + j], rtol=1e-3, atol=1e-3)
            assert_allclose(np.asarray(v_new)[0, j], np.asarray(v_all)[0, base + j], rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("tp", [1, 2])
    def test_attn_shard_verify_reassembles(self, tp):
        """TP verify shards + all-reduce + host residual + mlp_shard with
        rows=B*K must equal layer_full_verify — the coordinator's verify
        contract."""
        cfg = TINY
        params = make_layer_params(jax.random.PRNGKey(46), cfg)
        batch, k_win, s = 2, 4, cfg.max_seq
        lens = [8, 13]
        valid = jnp.asarray(lens, jnp.int32)
        base = valid - k_win
        x_win = jax.random.normal(jax.random.PRNGKey(47), (batch, k_win, cfg.hidden))
        k_all = jax.random.normal(jax.random.PRNGKey(48), (batch, s, cfg.hidden)) * 0.5
        v_all = jax.random.normal(jax.random.PRNGKey(49), (batch, s, cfg.hidden)) * 0.5
        prefix = jnp.arange(s)[None, :, None] < base[:, None, None]
        k_cache = jnp.where(prefix, k_all, 0.0)
        v_cache = jnp.where(prefix, v_all, 0.0)

        expect, k_ref, v_ref = M.build_layer_full_verify(cfg)(
            x_win, valid, k_cache, v_cache, *param_list(params, ALL)
        )

        hd = cfg.head_dim
        heads_local = cfg.n_heads // tp
        w = heads_local * hd
        shards = [M.shard_layer_params(params, tp, r, cfg.n_heads) for r in range(tp)]
        verify_fn = M.build_attn_shard_verify(cfg, tp)
        mlp_fn = M.build_mlp_shard(cfg, tp)
        parts = []
        for r, sh in enumerate(shards):
            sl = slice(r * w, (r + 1) * w)
            parts.append(
                verify_fn(
                    x_win, valid, k_cache[..., sl], v_cache[..., sl],
                    *param_list(sh, M.ATTN_PARAMS),
                )
            )
        attn_sum = sum(p[0] for p in parts)
        r_res = x_win + attn_sum
        r2 = r_res.reshape(batch * k_win, cfg.hidden)
        mlp_sum = sum(mlp_fn(r2, *param_list(sh, M.MLP_PARAMS))[0] for sh in shards)
        y = r_res + mlp_sum.reshape(batch, k_win, cfg.hidden)
        assert_allclose(np.asarray(y), np.asarray(expect), rtol=2e-3, atol=2e-3)
        k_cat = jnp.concatenate([p[1] for p in parts], axis=-1)
        v_cat = jnp.concatenate([p[2] for p in parts], axis=-1)
        assert_allclose(np.asarray(k_cat), np.asarray(k_ref), rtol=1e-3, atol=1e-3)
        assert_allclose(np.asarray(v_cat), np.asarray(v_ref), rtol=1e-3, atol=1e-3)

    def test_embed_verify_matches_embed_positions(self):
        cfg = TINY
        ids = jnp.array([[1, 5, 7, 9], [2, 2, 3, 4]], jnp.int32)
        wte = jax.random.normal(jax.random.PRNGKey(50), (cfg.vocab, cfg.hidden))
        wpe = jax.random.normal(jax.random.PRNGKey(51), (cfg.max_seq, cfg.hidden))
        (full,) = M.build_embed(cfg)(ids, wte, wpe)
        # verify the window ids[ :, 1:3] at base positions [1, 0]
        base = jnp.array([1, 0], jnp.int32)
        win = jnp.stack([ids[0, 1:3], ids[1, 0:2]])
        (y,) = M.build_embed_verify(cfg)(win, base, wte, wpe)
        for b, p in enumerate([1, 0]):
            for j in range(2):
                assert_allclose(np.asarray(y)[b, j], np.asarray(full)[b, p + j], rtol=1e-6)

    def test_verify_variants_lower(self):
        # the exact path aot.py takes must trace without concrete inputs
        for kind, kw in [
            ("embed_verify", dict(batch=2, seq=4)),
            ("layer_full_verify", dict(batch=2, seq=4)),
            ("attn_shard_verify", dict(batch=2, seq=2, tp=2)),
        ]:
            name, fn, args = M.variant(TINY, kind, **kw)
            jax.jit(fn).lower(*[s for _, s in args])


class TestVariantRegistry:
    def test_all_kinds_have_specs(self):
        for kind, kw, n_out in [
            ("embed", dict(batch=2, seq=16), 1),
            ("layer_full", dict(batch=2, seq=16), 1),
            ("attn_shard", dict(batch=2, seq=16, tp=2), 1),
            ("mlp_shard", dict(batch=2, seq=16, tp=2), 1),
            ("drce_attn_shard", dict(batch=2, seq=16, tp=2, t_bucket=16), 1),
            ("logits", dict(batch=2, seq=16), 1),
            ("embed_decode", dict(batch=2), 1),
            ("layer_full_kv", dict(batch=2, seq=16), 3),
            ("attn_shard_kv", dict(batch=2, seq=16, tp=2), 3),
            ("layer_full_decode", dict(batch=2), 3),
            ("attn_shard_decode", dict(batch=2, tp=2), 3),
            ("embed_verify", dict(batch=2, seq=4), 1),
            ("layer_full_verify", dict(batch=2, seq=4), 3),
            ("attn_shard_verify", dict(batch=2, seq=2, tp=2), 3),
        ]:
            name, fn, args = M.variant(TINY, kind, **kw)
            assert name.startswith("tiny_")
            out = jax.eval_shape(fn, *[s for _, s in args])
            assert len(out) == n_out, kind

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            M.variant(TINY, "nope")

    def test_params_per_layer_counts(self):
        cfg = M.PRESETS["gpt3"]
        # ~1.81e9 params/layer as the paper states for GPT3-175B (§4.4)
        assert 1.7e9 < cfg.params_per_layer() < 1.9e9
