#!/usr/bin/env bash
# Run the fleet benchmark (session-affine router throughput at 1/2/4
# replicas, plus a kill-and-failover cell with a seeded mid-run replica
# kill) and refresh BENCH_fleet.json at the repo root. A survivor-parity
# divergence through the kill, a lost session, or a leaked K/V block
# exits non-zero. BENCH_SMOKE=1 runs a smaller client pool (CI).
#
# Usage: scripts/bench_fleet.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench fleet "$@"

out="$(cd .. && pwd)/BENCH_fleet.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
