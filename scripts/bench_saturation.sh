#!/usr/bin/env bash
# Run the saturation benchmark (seeded hostile-traffic client pool with
# mid-stream disconnects and an injected worker stall vs. an unfaulted
# control run) and refresh BENCH_saturation.json at the repo root. A
# survivor-parity divergence or a leaked K/V block exits non-zero.
# BENCH_SMOKE=1 runs a smaller client pool (CI).
#
# Usage: scripts/bench_saturation.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench saturation "$@"

out="$(cd .. && pwd)/BENCH_saturation.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
