#!/usr/bin/env bash
# Run the shared-prefix reuse benchmark (templated traffic — a few shared
# prompt templates over most fresh prompts — against the same engine with
# the prefix cache off vs on) and refresh BENCH_prefix.json at the repo
# root. A completed-stream parity divergence between the cells or a
# leaked K/V block exits non-zero. BENCH_SMOKE=1 runs a smaller client
# pool (CI).
#
# Usage: scripts/bench_prefix.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench prefix_reuse "$@"

out="$(cd .. && pwd)/BENCH_prefix.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
