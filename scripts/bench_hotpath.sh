#!/usr/bin/env bash
# Run the hot-path microbenchmarks with fixed iteration counts and refresh
# BENCH_hotpath.json at the repo root (the perf-trajectory file later PRs
# compare against — see EXPERIMENTS.md §Perf).
#
# Usage: scripts/bench_hotpath.sh [extra cargo args...]
#
# The bench itself uses fixed warmup/iteration counts (no adaptive
# sampling), so runs are comparable across commits on the same machine.
set -euo pipefail

cd "$(dirname "$0")/../rust"

# benches tolerate a missing artifacts/ dir (engine + PJRT sections are
# skipped), but warn loudly since the engine round-trip number is the
# headline metric
if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — engine/PJRT benches will be skipped (run 'make artifacts')" >&2
fi

cargo bench --bench hotpath "$@"

echo "refreshed $(cd .. && pwd)/BENCH_hotpath.json"
