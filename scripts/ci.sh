#!/usr/bin/env bash
# CI gate: release build, full test suite, formatting. Keep this pinned to
# exactly what the repo's tier-1 verification runs so local and CI results
# agree.
#
# Usage: scripts/ci.sh
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release

# tier-1 tests, with a per-suite pass/fail summary at the end so CI logs
# show *which* integration suite regressed, not just that one did
test_log="$(mktemp)"
trap 'rm -f "$test_log"' EXIT
test_rc=0
cargo test 2>&1 | tee "$test_log" || test_rc=$?
echo
echo "== tier-1 per-suite summary =="
awk '
    # "Running unittests src/lib.rs (target/…)" / "Running tests/foo.rs (target/…)"
    /^[[:space:]]+Running / { suite = ($2 == "unittests") ? $3 : $2 }
    /^[[:space:]]+Doc-tests / { suite = "doc-tests " $2 }
    /^test result:/ {
        status = ($3 == "ok.") ? "PASS" : "FAIL"
        printf "  %-4s %-40s %s\n", status, suite, $0
    }
' "$test_log"
if [ "$test_rc" -ne 0 ]; then
    echo "tier-1 tests FAILED (exit $test_rc)" >&2
    exit "$test_rc"
fi

cargo fmt --check

# decode-bench smoke: one prefix, few tokens — catches decode-path and
# BENCH_decode.json regressions without the full sweep's runtime
BENCH_SMOKE=1 cargo bench --bench decode

# kvspill smoke: a small concurrent-session wave through a capped device
# tier — catches tiering regressions (parity failure exits non-zero) and
# refreshes BENCH_kvspill.json
BENCH_SMOKE=1 cargo bench --bench kvspill

# speculative-decode smoke: plain vs draft-and-verify on the repetitive
# workload — a stream divergence or tokens-per-pass <= 1.3 exits
# non-zero, and BENCH_specdecode.json is refreshed
BENCH_SMOKE=1 cargo bench --bench specdecode

# chaos smoke: the seeded saturation scenario (fixed seed, 25% mid-stream
# disconnects + a worker-delay fault window, admission caps) against an
# unfaulted control — leaked K/V blocks or a survivor-stream divergence
# exits non-zero, clean shutdown is implied by the bench returning, and
# BENCH_saturation.json is refreshed
BENCH_SMOKE=1 cargo bench --bench saturation

# prefix-reuse smoke: templated traffic with the prefix cache off vs on —
# a completed-stream divergence between the cells or a leaked K/V block
# (shared blocks included) exits non-zero, and BENCH_prefix.json is
# refreshed
BENCH_SMOKE=1 cargo bench --bench prefix_reuse

# fleet smoke: replica-router throughput at 1/2/4 replicas plus the
# kill-and-failover cell (one replica killed mid-run on the seeded
# schedule) — a survivor divergence through the kill, a lost session, or
# a leaked K/V block exits non-zero, and BENCH_fleet.json is refreshed
BENCH_SMOKE=1 cargo bench --bench fleet

# chunked-prefill smoke: the mixed long/short-prompt workload with
# chunking off vs on — a completed-stream divergence between the cells, a
# leaked K/V block, or a chunked max-TPOT materially above the monolithic
# cell's exits non-zero, and BENCH_chunked.json is refreshed
BENCH_SMOKE=1 cargo bench --bench chunked_prefill

# peer-tier smoke: the overflow wave through resident / host-only /
# peer+host / peer+copier cells — a stream divergence from the resident
# baseline, a leaked block on any tier, or a copier stall regression
# exits non-zero, and BENCH_peer.json is refreshed
BENCH_SMOKE=1 cargo bench --bench peer_pool
