#!/usr/bin/env bash
# CI gate: release build, full test suite, formatting. Keep this pinned to
# exactly what the repo's tier-1 verification runs so local and CI results
# agree.
#
# Usage: scripts/ci.sh
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo fmt --check

# decode-bench smoke: one prefix, few tokens — catches decode-path and
# BENCH_decode.json regressions without the full sweep's runtime
BENCH_SMOKE=1 cargo bench --bench decode

# kvspill smoke: a small concurrent-session wave through a capped device
# tier — catches tiering regressions (parity failure exits non-zero) and
# refreshes BENCH_kvspill.json
BENCH_SMOKE=1 cargo bench --bench kvspill
