#!/usr/bin/env bash
# Run the tiered-KV-cache benchmark (concurrent sessions served by a
# capped device slab + host spill tier vs. the resident-only baseline)
# and refresh BENCH_kvspill.json at the repo root. BENCH_SMOKE=1 runs a
# smaller session wave (CI).
#
# Usage: scripts/bench_kvspill.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench kvspill "$@"

out="$(cd .. && pwd)/BENCH_kvspill.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
