#!/usr/bin/env bash
# Run the chunked-prefill benchmark (a mixed long/short-prompt workload
# against the same engine with chunking off vs on) and refresh
# BENCH_chunked.json at the repo root. A completed-stream parity
# divergence between the cells, a leaked K/V block, or a chunked max-TPOT
# materially above the monolithic cell's exits non-zero. BENCH_SMOKE=1
# runs a smaller client pool (CI).
#
# Usage: scripts/bench_chunked.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench chunked_prefill "$@"

out="$(cd .. && pwd)/BENCH_chunked.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
