#!/usr/bin/env bash
# Run the three-tier KV-cache benchmark (resident / host-only spill /
# peer+host inline / peer+host with the overlapped copier) and refresh
# BENCH_peer.json at the repo root. A token-stream divergence between
# any cell and the resident baseline, a leaked block on any tier, or a
# copier stall regression exits non-zero. BENCH_SMOKE=1 runs a smaller
# session wave (CI).
#
# Usage: scripts/bench_peer.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench peer_pool "$@"

out="$(cd .. && pwd)/BENCH_peer.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
