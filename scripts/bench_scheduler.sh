#!/usr/bin/env bash
# Run the iteration-level scheduler benchmark (single-client vs coalesced
# multi-client decode) and refresh BENCH_scheduler.json at the repo root.
#
# Usage: scripts/bench_scheduler.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench scheduler "$@"

out="$(cd .. && pwd)/BENCH_scheduler.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
