#!/usr/bin/env bash
# Run the incremental-decode benchmark (per-token latency vs prefix length,
# paged KV cache vs re-prefill) and refresh BENCH_decode.json at the repo
# root. BENCH_SMOKE=1 runs a fast single-prefix sanity pass (CI).
#
# Usage: scripts/bench_decode.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench decode "$@"

out="$(cd .. && pwd)/BENCH_decode.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
