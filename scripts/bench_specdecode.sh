#!/usr/bin/env bash
# Run the speculative-decode benchmark (plain vs draft-and-verify at
# k∈{2,4}, repetitive vs adversarial prompts, n-gram vs replay drafter)
# and refresh BENCH_specdecode.json at the repo root. A speculative
# stream diverging from plain decode exits non-zero. BENCH_SMOKE=1 runs
# a single-workload pass (CI).
#
# Usage: scripts/bench_specdecode.sh [extra cargo args...]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! ls ../artifacts/manifest.json >/dev/null 2>&1 && ! ls artifacts/manifest.json >/dev/null 2>&1; then
    echo "warning: no AOT artifacts found — the bench will skip (run 'make artifacts')" >&2
fi

cargo bench --bench specdecode "$@"

out="$(cd .. && pwd)/BENCH_specdecode.json"
if [ -f "$out" ]; then
    echo "refreshed $out"
else
    echo "warning: $out was not written (bench skipped?)" >&2
fi
