//! End-to-end serving driver (the DESIGN.md headline experiment): load a
//! small real model (AOT artifacts through PJRT), serve an open-loop
//! Poisson request stream with variable lengths through the full
//! hierarchy-controller stack — batcher → consistency queue → workers —
//! and report latency percentiles + throughput.
//!
//! Run with: `cargo run --release --example serve_batch -- [--preset tiny]
//!            [--tp 2] [--drce] [--rate 40] [--requests 200] [--seconds 10]`

use energonai::coordinator::engine::{Engine, LaunchConfig};
use energonai::util::cli::Args;
use energonai::workload::{Generator, LengthDist};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let preset = args.get_or("preset", "tiny");
    let tp = args.usize("tp", 1);
    let pp = args.usize("pp", 1);
    let drce = args.flag("drce");
    let rate = args.f64("rate", 50.0);
    let n_requests = args.usize("requests", 200);

    let engine = Engine::launch(
        LaunchConfig::preset(preset)
            .with_parallel(tp, pp)
            .with_drce(drce)
            .with_warmup(true),
    )?;
    let max_len = engine
        .manifest
        .shape_points(preset)
        .iter()
        .map(|&(_, s)| s)
        .max()
        .unwrap();
    println!(
        "serving {} (tp={tp} pp={pp} drce={drce}) — poisson {rate} req/s, {n_requests} requests, lens 1..{max_len}",
        engine.cfg
    );

    // open-loop client: Poisson arrivals, heavy-tailed lengths (the
    // variable-length reality DRCE targets, §4.3)
    let mut gen = Generator::new(1234, LengthDist::HeavyTail(max_len, 1.1), engine.cfg.vocab);
    let t0 = Instant::now();
    // per-request waiter threads record completion latency at fulfilment
    // (client-observed: includes batch-formation queueing)
    let lat = std::sync::Arc::new(std::sync::Mutex::new(Vec::with_capacity(n_requests)));
    let mut waiters = Vec::with_capacity(n_requests);
    for _ in 0..n_requests {
        let req = gen.request();
        let sent = Instant::now();
        let fut = engine.submit(req.tokens)?;
        let lat = lat.clone();
        waiters.push(std::thread::spawn(move || {
            let tok = fut.to_here();
            lat.lock().unwrap().push(sent.elapsed().as_secs_f64() * 1e3);
            tok
        }));
        std::thread::sleep(gen.next_gap(rate));
    }
    let submit_done = t0.elapsed();
    for w in waiters {
        w.join().unwrap()?;
    }
    let wall = t0.elapsed();
    let mut latencies = std::sync::Arc::try_unwrap(lat).unwrap().into_inner().unwrap();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p) as usize];
    println!("\n== results ==");
    println!("submitted {n_requests} in {:.2}s; completed in {:.2}s", submit_done.as_secs_f64(), wall.as_secs_f64());
    println!(
        "request latency: p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms  max {:.1}ms",
        pct(0.5),
        pct(0.9),
        pct(0.99),
        latencies.last().unwrap()
    );
    println!("throughput: {:.1} req/s", n_requests as f64 / wall.as_secs_f64());
    println!("engine: {}", engine.metrics_snapshot().summary());
    engine.shutdown();
    Ok(())
}
