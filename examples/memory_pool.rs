//! Peer memory pooling (PMEP, §4.4) vs BMInf-style CPU offload (§5.6) on
//! a live engine: the same model runs with all layers resident, with
//! layers pooled in peer memory (async prefetch), and with synchronous
//! host offload — all three must produce identical logits; the pooled
//! runs report their copy/stall statistics.
//!
//! Run with: `cargo run --release --example memory_pool -- [--preset tiny]
//!            [--local 2] [--batches 8]`

use energonai::config::ModelConfig;
use energonai::coordinator::engine::{Engine, LaunchConfig, MemoryMode};
use energonai::coordinator::Request;
use energonai::memory::ledger::even_offload_placement;
use energonai::memory::pool::PoolConfig;
use energonai::perf::DeviceModel;
use energonai::sim::pmep::{self, PmepQuery};
use energonai::util::cli::Args;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let preset = args.get_or("preset", "tiny");
    let n_local = args.usize("local", 2);
    let batches = args.usize("batches", 8);

    let cfg = ModelConfig::preset(preset).unwrap();
    println!(
        "{}: {} layers, keeping {n_local} resident -> offloading {:?}\n",
        cfg,
        cfg.n_layers,
        even_offload_placement(cfg.n_layers, n_local)
    );

    let mut reference = None;
    for (mode, label) in [
        (MemoryMode::Resident, "resident"),
        (
            MemoryMode::Pmep { n_local, pool: PoolConfig::pmep() },
            "pmep (peer + prefetch)",
        ),
        (MemoryMode::Bminf { n_local }, "bminf (sync host)"),
    ] {
        let engine = Engine::launch(
            LaunchConfig::preset(preset).with_memory(mode).with_warmup(true),
        )?;
        let t0 = Instant::now();
        let mut last = None;
        for k in 0..batches as u64 {
            let r = engine.infer_batch(vec![Request::new(k, vec![7, 8, 9, 10])])?;
            last = Some(r.to_here()?);
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / batches as f64;
        let logits = last.unwrap().logits;
        match &reference {
            None => reference = Some(logits),
            Some(expect) => {
                let diff = logits.max_abs_diff(expect);
                anyhow::ensure!(diff < 1e-4, "{label} diverged by {diff}");
            }
        }
        println!("{label:<24} {ms:>8.2} ms/batch   (numerics match ✓)");
        engine.shutdown();
    }

    // paper-scale projection for the same placement policy (Fig. 13)
    println!("\npaper-scale projection (GPT-3 layers, A100 model, bs=32 pad=64):");
    let dev = DeviceModel::default();
    let base = pmep::resident_tflops(&ModelConfig::preset("gpt3").unwrap().with_layers(20), &dev, 32, 64);
    for n in [24usize, 30, 40] {
        let gcfg = ModelConfig::preset("gpt3").unwrap().with_layers(n);
        let p = pmep::run(&PmepQuery::pmep(gcfg.clone(), 20, 32, 64), &dev);
        let b = pmep::run(&PmepQuery::bminf(gcfg, 20, 32, 64), &dev);
        println!(
            "  {n}-layer: pmep {:.0} TFLOPS ({:.1}% loss), bminf {:.0} TFLOPS ({:.1}% loss)",
            p.tflops,
            (1.0 - p.tflops / base) * 100.0,
            b.tflops,
            (1.0 - b.tflops / base) * 100.0
        );
    }
    Ok(())
}
