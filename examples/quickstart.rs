//! Quickstart: the paper's Fig. 9 usage, end to end.
//!
//! ```text
//! engine = InferenceEngine(model, config)
//! rref = engine(input)          # non-blocking
//! output = rref.to_here()
//! ```
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
use energonai::coordinator::Request;

fn main() -> anyhow::Result<()> {
    // 1. launch: initializes the global communication context (worker
    //    threads + collective endpoints) and the RPC context (command bus)
    let engine = Engine::launch(LaunchConfig::preset("tiny").with_warmup(true))?;
    println!("engine up: {}", engine.cfg);

    // 2. non-blocking submit — returns a remote reference immediately
    let rref = engine.infer_batch(vec![
        Request::new(0, vec![12, 7, 42, 3, 99]),
        Request::new(1, vec![5, 5, 5]),
    ])?;
    println!("submitted (rref uid {}), doing other work...", rref.uid);

    // 3. fetch the result whenever it is required
    let out = rref.to_here()?;
    println!("next tokens: {:?}", out.next_tokens);
    println!("logits shape: {:?}", out.logits.shape);

    // the same through the dynamic batcher, one request at a time
    let futures: Vec<_> = (0..4)
        .map(|i| engine.submit(vec![i + 1, i + 2, i + 3]).unwrap())
        .collect();
    for (i, f) in futures.iter().enumerate() {
        println!("batched request {i} -> token {}", f.to_here()?);
    }

    // 4. streaming generation: a session re-enters the batcher after every
    //    step, so concurrent generations coalesce into shared buckets
    let gref = engine.generate_stream(GenRequest::new(vec![12, 7, 42], 6))?;
    print!("generated:");
    while let Some(tok) = gref.next()? {
        print!(" {tok}");
    }
    println!("\nfull sequence: {:?}", gref.to_here()?);

    println!("{}", engine.metrics_snapshot().summary());
    engine.shutdown();
    Ok(())
}
